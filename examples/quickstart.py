"""Quickstart: the whole system in one file.

1. builds a reduced gemma3-style model (5:1 sliding:global pattern),
2. trains it a few steps on the deterministic synthetic pipeline,
3. serves it (prefill + decode with cache, correctness-checked),
4. runs the paper's static-schedule machinery: builds the Octa matmul
   schedule, simulates it, checks WCET, and prints the TPU mapping.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.configs.multivic_paper import OCTA, PAPER_MEDIAN_CYCLES
from repro.core import (MatmulProblem, build_matmul_schedule, run_many,
                        wcet)
from repro.core.tpu_mapping import tpu_matmul_schedule, tpu_wcet
from repro.data.pipeline import DataConfig
from repro.launch.train import reduced_config
from repro.models import decode_step, prefill
from repro.models.lm import RunOptions
from repro.runtime.trainer import Trainer


def main():
    print("=== 1+2. train a reduced gemma3 (sliding-window pattern) ===")
    import argparse
    args = argparse.Namespace(layers=6, d_model=128, vocab=512)
    cfg = reduced_config(get_config("gemma3-12b"), args)
    opts = RunOptions(chunk_q=32, chunk_kv=32, loss_chunk=32, remat=False)
    tr = Trainer(cfg, TrainConfig(learning_rate=5e-3, warmup_steps=5),
                 DataConfig(vocab_size=cfg.vocab_size, global_batch=8,
                            seq_len=64),
                 opts=opts, log_every=5)
    hist = tr.run(15)
    print(f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")

    print("=== 3. serve it ===")
    params = tr.final_state.params
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 32), 0,
                              cfg.vocab_size)
    sopts = RunOptions(chunk_q=32, chunk_kv=32, cache_len=40,
                       remat=False)
    logits, cache = prefill(cfg, params, {"tokens": toks,
                                          "targets": toks}, sopts)
    out = []
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)
    for i in range(8):
        logits, cache = decode_step(cfg, params, cache, tok, 32 + i,
                                    sopts)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)
        out.append(tok)
    print("generated:", jnp.stack(out, 1))

    print("=== 4. the paper's static schedule (Octa, 1024^3 matmul) ===")
    sched = build_matmul_schedule(OCTA, MatmulProblem())
    stats = run_many(sched, OCTA, n_runs=10)
    bound = wcet(sched, OCTA)
    print(f"median {stats['median']:.0f} cycles "
          f"(paper: {PAPER_MEDIAN_CYCLES['octa']}; "
          f"err {stats['median']/PAPER_MEDIAN_CYCLES['octa']-1:+.3%})")
    print(f"sigma {stats['std']:.0f} cycles; WCET {bound:.0f} "
          f"(all runs <= WCET: {stats['max'] <= bound})")

    tsched = tpu_matmul_schedule(1024, 1024, 1024, n_devices=1)
    print(f"same workload on the TPU target: WCET bound "
          f"{tpu_wcet(tsched)*1e6:.1f} us "
          f"(vmem plan ok: {tsched.meta['vmem_ok']})")


if __name__ == "__main__":
    main()
