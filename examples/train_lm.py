"""End-to-end training driver with fault tolerance.

Trains a decoder LM on the deterministic synthetic pipeline with
periodic checkpointing, then SIMULATES A PREEMPTION mid-run and shows
the restart resuming from the checkpoint (bit-exact data stream).

Defaults are CPU-sized; --full --steps 300 with a TPU mesh trains the
~100M-parameter config end to end.

  PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs import TrainConfig, get_config
from repro.data.pipeline import DataConfig
from repro.models.lm import RunOptions
from repro.runtime.trainer import Trainer


def build_cfg(full: bool):
    cfg = get_config("qwen2-0.5b")
    if full:
        # ~100M params: 12 layers, d=768 (the "train ~100M" driver)
        return dataclasses.replace(
            cfg, num_layers=12, d_model=768, d_ff=2048,
            vocab_size=32_000, vocab_pad_multiple=128,
            attention=dataclasses.replace(cfg.attention, num_heads=12,
                                          num_kv_heads=4, head_dim=64))
    return dataclasses.replace(
        cfg, num_layers=2, d_model=128, d_ff=256, vocab_size=512,
        vocab_pad_multiple=64,
        attention=dataclasses.replace(cfg.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=32))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = build_cfg(args.full)
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=10,
                       total_steps=args.steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size,
                      global_batch=args.batch, seq_len=args.seq)
    opts = RunOptions(chunk_q=32, chunk_kv=32, loss_chunk=32,
                      remat=False)
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")

    half = args.steps // 2
    print(f"--- phase 1: train to step {half}, then 'preempt' ---")
    tr1 = Trainer(cfg, tcfg, dcfg, ckpt_dir=ckpt, ckpt_every=10,
                  opts=opts, log_every=10)
    tr1.on_metrics = lambda step, m: (
        tr1.guard.trigger_for_test() if step == half else None)
    tr1.run(args.steps)
    print(f"preempted at step {tr1.final_state.step}; "
          f"checkpoint: {tr1.ckpt.latest_step()}")

    print("--- phase 2: relaunch; resumes from the checkpoint ---")
    tr2 = Trainer(cfg, tcfg, dcfg, ckpt_dir=ckpt, ckpt_every=10,
                  opts=opts, log_every=10)
    hist = tr2.run(args.steps)
    print(f"final loss {hist['loss'][-1]:.4f} at step "
          f"{tr2.final_state.step} "
          f"(stragglers flagged: {len(tr2.straggler.events)})")


if __name__ == "__main__":
    main()
