"""The paper core as a library walk-through: for every MultiVic design
point, build the static matmul schedule, verify interference freedom,
simulate the 100-run protocol, compute WCET bounds, and print the
roofline + F_max + resource models — i.e. reproduce the paper's whole
evaluation from the public API.

  PYTHONPATH=src python examples/schedule_analysis.py [--runs 20]
                                                      [--trace DIR]

``--trace DIR`` additionally dumps one seeded execution per design
point as ``DIR/<name>.trace.json`` — open in chrome://tracing or
Perfetto to see the static schedule as a per-resource Gantt chart.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.multivic_paper import (EVAL_CONFIGS,
                                          PAPER_MEDIAN_CYCLES)
from repro.core import (MatmulProblem, build_matmul_schedule, run_many,
                        schedule_totals, spm_plan, wcet,
                        wcet_closed_form, jitter_bound)
from repro.core.fmax import predict_fmax_mhz
from repro.core.resources import total_resources
from repro.core.roofline import config_roofline
from repro.core.simulator import simulate
from repro.obs import TraceRecorder, write_chrome_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=20)
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="dump per-config Chrome traces into DIR")
    args = ap.parse_args()
    prob = MatmulProblem()
    if args.trace:
        os.makedirs(args.trace, exist_ok=True)

    for hw in EVAL_CONFIGS:
        plan = spm_plan(hw, prob)
        sched = build_matmul_schedule(hw, prob)
        sched.validate_interference_freedom()
        tot = schedule_totals(sched)
        stats = run_many(sched, hw, n_runs=args.runs)
        bound = wcet(sched, hw)
        closed = wcet_closed_form(sched, hw)
        roof = config_roofline(hw)
        res = total_resources(hw)
        target = PAPER_MEDIAN_CYCLES.get(hw.name)
        print(f"\n== {hw.name} ({hw.num_worker_cores} cores, "
              f"VREG {hw.vicuna.vreg_bits}b, MUL "
              f"{hw.vicuna.mul_width_bits}b) ==")
        print(f" SPM plan: B-block width {plan['bw']} cols, "
              f"{plan['n_rounds']} rounds, fits={plan['fits']}")
        print(f" schedule: {tot['n_phases']} phases "
              f"({tot['n_dma']} DMA), {tot['macs']:.3g} MACs, "
              f"{tot['dma_bytes']/1e6:.1f} MB DMA traffic")
        print(f" sim: median {stats['median']:.0f} cy, "
              f"sigma {stats['std']:.0f} cy"
              + (f", paper err {stats['median']/target-1:+.3%}"
                 if target else ""))
        print(f" WCET: exact {bound:.0f} <= closed-form {closed:.0f}; "
              f"jitter bound {jitter_bound(sched):.0f} cy")
        print(f" @F_max {hw.fmax_hz/1e6:.0f} MHz "
              f"(model {predict_fmax_mhz(hw):.1f}): "
              f"{stats['median']/hw.fmax_hz:.2f} s")
        print(f" roofline: {roof['peak_gflops']:.1f} GFLOP/s peak, "
              f"SPM {roof['spm_bw_gbs']:.2f} GB/s")
        print(f" resources: {res['lut']:.0f} LUT, {res['dsp']:.0f} DSP, "
              f"{res['bram']:.0f} BRAM")
        if args.trace:
            rec = TraceRecorder(time_unit="cycles")
            simulate(sched, hw, seed=0, trace=rec)
            path = os.path.join(args.trace, f"{hw.name}.trace.json")
            write_chrome_trace(rec, path)
            print(f" trace: {path} ({len(rec.spans)} spans)")


if __name__ == "__main__":
    main()
