"""Batched serving with time-predictability reporting — the paper's
Fig. 4 protocol applied to LM decode: run the same static step many
times, report median / sigma / jitter, and compare with the WCET bound
from the static-schedule model.

  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve  # noqa: E402  (reuses the launcher)

if __name__ == "__main__":
    sys.argv.setdefault if False else None
    serve.main()
