"""Autotuner walk-through: tune, cache, reuse.

1. cold-tunes two kernels on small shapes — enumerate candidate block
   plans, prune with the VMEM + roofline model, measure the survivors
   under a TraceRecorder, select by the jitter-aware objective
   (p99 latency, CoV tie-break),
2. re-tunes the same problems: the persistent plan cache answers with
   ZERO measurements (watch the span counts),
3. calls the public kernel wrappers with no block arguments and shows
   them picking the tuned plans up from the cache.

Uses a throwaway cache under /tmp so it never touches your real
~/.cache/repro/tuning_plans.json.

  PYTHONPATH=src python examples/autotune_kernels.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# throwaway cache + autotuning on, BEFORE any repro import resolves it
_cache_path = os.path.join(tempfile.mkdtemp(prefix="repro_tune_"),
                           "plans.json")
os.environ["REPRO_PLAN_CACHE"] = _cache_path
os.environ["REPRO_AUTOTUNE"] = "1"

from repro import tuning
from repro.obs import TraceRecorder
from repro.tuning import (MatmulProblem, WkvProblem, cache_key,
                          cost_summary, measurement_count, plan_sig,
                          tune)

PROBLEMS = [("spm_matmul", MatmulProblem(128, 128, 128)),
            ("wkv6", WkvProblem(1, 64, 2, 32))]


def main():
    print(f"=== 1. cold tune (cache: {_cache_path}) ===")
    trace = TraceRecorder()
    for kernel, problem in PROBLEMS:
        res = tune(kernel, problem, reps=3, warmup=1, interpret=True,
                   trace=trace)
        print(f"{kernel} {problem.sig}: plan={plan_sig(res.plan)} "
              f"[{res.source}] candidates={res.candidates} "
              f"feasible={res.feasible} measured={res.measured} "
              f"p99_us={res.stats.p99:.1f} cov={res.stats.cov:.4f}")
        model = cost_summary(kernel, problem, res.plan)
        print(f"  model: {model['flops']/1e6:.1f} MFLOP, "
              f"{model['bytes']/1e3:.0f} KB moved, "
              f"{model['grid_steps']:.0f} grid steps, "
              f"vmem {model['vmem_need']/1e3:.0f} KB")
    print(f"cold measurement spans: {measurement_count(trace)}")

    print("\n=== 2. warm tune: zero measurements ===")
    trace2 = TraceRecorder()
    for kernel, problem in PROBLEMS:
        res = tune(kernel, problem, reps=3, interpret=True,
                   trace=trace2)
        print(f"{kernel}: plan={plan_sig(res.plan)} [{res.source}] "
              f"measured={res.measured}")
    print(f"warm measurement spans: {measurement_count(trace2)}")
    assert measurement_count(trace2) == 0

    print("\n=== 3. wrappers pick the tuned plans up ===")
    import jax

    from repro.kernels.spm_matmul.ops import matmul
    p = PROBLEMS[0][1]
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (p.m, p.k))
    b = jax.random.normal(kb, (p.k, p.n))
    cache = tuning.active_cache()
    hits0 = cache.hits
    out = matmul(a, b, interpret=True)   # no block args passed
    entry = cache.entry(cache_key("spm_matmul", p))
    print(f"matmul({p.m}x{p.k}x{p.n}) -> {out.shape}, "
          f"cache hits {hits0} -> {cache.hits}, "
          f"cached plan {plan_sig(entry['plan'])} "
          f"(tuned on {entry['env']['backend']})")
    assert cache.hits == hits0 + 1


if __name__ == "__main__":
    main()
