#!/usr/bin/env python
"""Compat-seam lint: version-sensitive JAX symbols may only be touched
inside src/repro/compat.py.

Greps every .py file in the repo for direct references to
  * the Pallas TPU compiler-params class (either spelling),
  * the jax.sharding axis-type enum (attribute or from-import),
  * shard_map imported from jax rather than repro.compat,
and fails if any appear outside the allowlist.  Run directly or via
tests/test_compat_lint.py (tier-1).

SCAN_DIRS is the whole tree that may contain Python — src (including
src/repro/obs and src/repro/tuning), tests, scripts, benchmarks,
examples; new top-level code directories must be added here
(tests/test_compat_lint.py pins the expected scope).

The patterns below are built by string concatenation so this file does
not flag itself.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# Only repro.compat may touch the raw symbols.
ALLOWLIST = {"src/repro/compat.py"}

SCAN_DIRS = ("src", "tests", "scripts", "benchmarks", "examples")

PATTERNS = [
    ("Pallas TPU compiler params (use repro.compat.tpu_compiler_params)",
     re.compile(r"\b(?:TPU)?Compiler" + r"Params\b")),
    ("jax.sharding axis-type enum (use repro.compat.AxisType)",
     re.compile(r"jax\.sharding\.Axis" + r"Type\b")),
    ("axis-type enum from-import (use repro.compat.AxisType)",
     re.compile(r"from\s+jax\.sharding\s+import\s+[^\n]*\bAxis"
                + r"Type\b")),
    ("shard_map from jax (use repro.compat.shard_map)",
     re.compile(r"from\s+jax(?:\.experimental(?:\.shard_map)?)?\s+"
                r"import\s+[^\n]*\bshard_" + r"map\b")),
]


def find_violations(root: pathlib.Path = REPO_ROOT):
    violations = []
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel in ALLOWLIST:
                continue
            text = path.read_text(encoding="utf-8", errors="replace")
            for lineno, line in enumerate(text.splitlines(), 1):
                for why, pat in PATTERNS:
                    if pat.search(line):
                        violations.append((rel, lineno, why,
                                           line.strip()))
    return violations


def main() -> int:
    violations = find_violations()
    for rel, lineno, why, line in violations:
        print(f"{rel}:{lineno}: {why}\n    {line}")
    if violations:
        print(f"\n{len(violations)} compat violation(s); route these "
              "through src/repro/compat.py", file=sys.stderr)
        return 1
    print("compat-import lint clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
