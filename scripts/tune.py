#!/usr/bin/env python
"""Offline jitter-aware kernel autotuner CLI (repro.tuning).

Tunes registered Pallas kernels and persists the winning block plans
to the JSON plan cache, so later runs — benchmarks, serving, or this
script again — reuse them with ZERO measurements (the final
``measurement spans`` line is the proof: it counts the timed reps
recorded on the obs trace, and a fully warm cache prints 0).

  # tune every registered kernel on the benchmark shapes
  PYTHONPATH=src python scripts/tune.py

  # one kernel, explicit shape/dtype, fresh measurements
  PYTHONPATH=src python scripts/tune.py --kernel spm_matmul \
      --shape 512x512x512 --dtype bfloat16 --force

Shape syntax per kernel: spm_matmul MxKxN; flash_attention BxSxHxKVxD
(causal, Sq=Sk=S); wkv6 BxSxHxK.  Cache path: --cache, else
$REPRO_PLAN_CACHE, else ~/.cache/repro/tuning_plans.json.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None) -> int:
    from repro.kernels import registered_kernels
    ap = argparse.ArgumentParser(
        description="offline jitter-aware kernel autotuner")
    ap.add_argument("--kernel", action="append",
                    choices=registered_kernels(),
                    help="kernel(s) to tune (default: all registered)")
    ap.add_argument("--shape", default=None,
                    help="kernel-specific shape (single --kernel only)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--reps", type=int, default=5,
                    help="timed reps per surviving candidate")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--max-candidates", type=int, default=4,
                    help="plans measured after analytic pruning")
    ap.add_argument("--cache", default=None,
                    help="plan-cache path (default: $REPRO_PLAN_CACHE)")
    ap.add_argument("--force", action="store_true",
                    help="re-measure even on a warm cache")
    args = ap.parse_args(argv)

    from repro.obs import TraceRecorder
    from repro.tuning import (DEFAULT_PROBLEMS, PlanCache,
                              measurement_count, parse_problem,
                              plan_sig, tune)

    kernels = args.kernel or registered_kernels()
    if args.shape and len(kernels) != 1:
        ap.error("--shape needs exactly one --kernel")
    jobs = []
    for kern in kernels:
        problem = (parse_problem(kern, args.shape, args.dtype)
                   if args.shape else DEFAULT_PROBLEMS[kern])
        jobs.append((kern, problem))

    cache = PlanCache(args.cache) if args.cache else None
    trace = TraceRecorder()
    for kern, problem in jobs:
        res = tune(kern, problem, cache=cache, reps=args.reps,
                   warmup=args.warmup,
                   max_candidates=args.max_candidates,
                   force=args.force, trace=trace)
        line = (f"{kern} {problem.sig}: plan={plan_sig(res.plan)} "
                f"[{res.source}] measured={res.measured}")
        if res.stats is not None:
            line += (f" p99_us={res.stats.p99:.1f} "
                     f"cov={res.stats.cov:.4f} "
                     f"(candidates={res.candidates} "
                     f"feasible={res.feasible} "
                     f"pruned_to={res.pruned_to})")
        print(line)
    print(f"plan cache: {(cache or PlanCache()).path}")
    print(f"measurement spans: {measurement_count(trace)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
