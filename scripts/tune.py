#!/usr/bin/env python
"""Offline jitter-aware autotuner CLI (repro.tuning).

Tunes registered Pallas kernels — or, with ``--model``, a whole
serving configuration — and persists the winning plans to the JSON
plan cache, so later runs — benchmarks, serving, or this script
again — reuse them with ZERO measurements (the final
``measurement spans`` line is the proof: it counts the timed reps
recorded on the obs trace, and a fully warm cache prints 0).

  # tune every registered kernel on the benchmark shapes
  PYTHONPATH=src python scripts/tune.py

  # one kernel, explicit shape/dtype, fresh measurements
  PYTHONPATH=src python scripts/tune.py --kernel spm_matmul \
      --shape 512x512x512 --dtype bfloat16 --force

  # a serving plan: prefill chunking + decode loop structure,
  # measured as full prefill+decode passes, cached under ``model|``
  PYTHONPATH=src python scripts/tune.py --model qwen2-0.5b \
      --shape 4x64x32

Shape syntax per kernel: spm_matmul MxKxN; flash_attention BxSxHxKVxD
(causal, Sq=Sk=S); wkv6 BxSxHxK; --model BxPxG (batch x prompt x gen,
model dims from --layers/--d-model/--vocab).  Cache path: --cache,
else $REPRO_PLAN_CACHE, else ~/.cache/repro/tuning_plans.json.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None) -> int:
    from repro.kernels import registered_kernels
    ap = argparse.ArgumentParser(
        description="offline jitter-aware autotuner")
    ap.add_argument("--kernel", action="append",
                    choices=registered_kernels(),
                    help="kernel(s) to tune (default: all registered)")
    ap.add_argument("--model", default=None, metavar="ARCH",
                    help="tune a serving plan for this architecture "
                         "instead of kernel block plans")
    ap.add_argument("--shape", default=None,
                    help="kernel-specific shape (single --kernel "
                         "only); BxPxG with --model")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--layers", type=int, default=2,
                    help="--model: reduced layer count (0 = full)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--reps", type=int, default=5,
                    help="timed reps per surviving candidate")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--max-candidates", type=int, default=4,
                    help="plans measured after analytic pruning")
    ap.add_argument("--cache", default=None,
                    help="plan-cache path (default: $REPRO_PLAN_CACHE)")
    ap.add_argument("--force", action="store_true",
                    help="re-measure even on a warm cache")
    args = ap.parse_args(argv)

    from repro.obs import TraceRecorder
    from repro.tuning import (DEFAULT_PROBLEMS, PlanCache,
                              measurement_count, parse_model_problem,
                              parse_problem, plan_sig, tune,
                              tune_model, us_per_token)

    if args.model and args.kernel:
        ap.error("--model and --kernel are mutually exclusive")

    cache = PlanCache(args.cache) if args.cache else None
    trace = TraceRecorder()

    if args.model:
        problem = parse_model_problem(
            args.model, args.shape or "4x64x32", layers=args.layers,
            d_model=args.d_model, vocab=args.vocab, dtype=args.dtype)
        res = tune_model(problem, cache=cache, reps=args.reps,
                         warmup=args.warmup,
                         max_candidates=args.max_candidates,
                         force=args.force, trace=trace)
        line = (f"model {problem.sig}: plan={plan_sig(res.plan)} "
                f"[{res.source}] measured={res.measured}")
        if res.stats is not None:
            line += (f" (candidates={res.candidates} "
                     f"feasible={res.feasible} "
                     f"pruned_to={res.pruned_to})")
        print(line)
        if res.stats is not None and res.default_stats is not None:
            d, t = res.default_stats, res.stats
            print(f"  tuned:   {us_per_token(t, problem):8.1f} us/tok  "
                  f"pass p99 {t.p99:.1f} us  cov {t.cov:.4f}")
            print(f"  default: {us_per_token(d, problem):8.1f} us/tok  "
                  f"pass p99 {d.p99:.1f} us  cov {d.cov:.4f}")
    else:
        kernels = args.kernel or registered_kernels()
        if args.shape and len(kernels) != 1:
            ap.error("--shape needs exactly one --kernel")
        jobs = []
        for kern in kernels:
            problem = (parse_problem(kern, args.shape, args.dtype)
                       if args.shape else DEFAULT_PROBLEMS[kern])
            jobs.append((kern, problem))

        for kern, problem in jobs:
            res = tune(kern, problem, cache=cache, reps=args.reps,
                       warmup=args.warmup,
                       max_candidates=args.max_candidates,
                       force=args.force, trace=trace)
            line = (f"{kern} {problem.sig}: plan={plan_sig(res.plan)} "
                    f"[{res.source}] measured={res.measured}")
            if res.stats is not None:
                line += (f" p99_us={res.stats.p99:.1f} "
                         f"cov={res.stats.cov:.4f} "
                         f"(candidates={res.candidates} "
                         f"feasible={res.feasible} "
                         f"pruned_to={res.pruned_to})")
            print(line)
    print(f"plan cache: {(cache or PlanCache()).path}")
    print(f"measurement spans: {measurement_count(trace)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
