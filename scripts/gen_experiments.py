"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the
experiments/ artifacts.  Run after dryrun/roofline sweeps:

  PYTHONPATH=src python scripts/gen_experiments.py > /tmp/tables.md
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

DRY = pathlib.Path("experiments/dryrun")
ROOF = pathlib.Path("experiments/roofline")

IMPROVE = {
    ("collective", "train"): (
        "weight all-gather traffic (FSDP) dominates; overlap gathers "
        "with the previous layer's compute and/or shard activations on "
        "the model axis (sequence parallelism) to shrink boundary "
        "collectives"),
    ("collective", "prefill"): (
        "FSDP weight gathers per layer dominate; switch serving to "
        "weight-stationary tensor parallelism (no per-layer weight "
        "movement, small activation all-reduces instead)"),
    ("collective", "decode"): (
        "per-token FSDP weight gathers dwarf the microscopic compute; "
        "decode must be weight-stationary (pure TP) so only activation "
        "all-reduces remain"),
    ("memory", "train"): (
        "activation traffic dominates; fuse block internals (flash "
        "kernels) and shard saved activations on the model axis"),
    ("memory", "prefill"): (
        "KV-cache writes and activation streams dominate; fuse "
        "attention (kernels/flash_attention) and keep KV sharded"),
    ("memory", "decode"): (
        "reading the weight shard per token is the floor; raise batch "
        "or quantize weights (int8) to halve bytes"),
    ("compute", "train"): (
        "compute-bound at the dispatch/attention einsums; remove "
        "non-useful FLOPs (gather-based MoE dispatch, causal-block "
        "skipping) to close the useful-ratio gap"),
    ("compute", "prefill"): (
        "compute-bound; improve useful-FLOP ratio via masked-block "
        "skipping in attention"),
    ("compute", "decode"): (
        "compute-bound only because the cell is tiny; batch requests "
        "to amortize"),
}


def dryrun_table():
    rows = ["| arch | shape | mesh | status | args GB/dev | temp GB/dev "
            "| HLO GFLOP/dev | collectives (count) |",
            "|---|---|---|---|---|---|---|---|"]
    for f in sorted(DRY.glob("*.json")):
        r = json.loads(f.read_text())
        m = r.get("memory", {})
        colls = r.get("collectives", {})
        cstr = ", ".join(f"{k}:{v['count']}" for k, v in sorted(
            colls.items())) or "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
            f"| {m.get('argument_bytes', 0)/1e9:.2f} "
            f"| {m.get('temp_bytes', 0)/1e9:.2f} "
            f"| {r.get('flops', 0)/1e9:.1f} | {cstr} |")
    return "\n".join(rows)


def roofline_table():
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | roofline frac | MODEL/HLO flops | "
            "what moves the dominant term |",
            "|---|---|---|---|---|---|---|---|---|"]
    for f in sorted(ROOF.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        t = r["terms"]
        note = IMPROVE.get((t["dominant"], _kind(r["shape"])), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} "
            f"| {t['memory_s']:.4f} | {t['collective_s']:.4f} "
            f"| **{t['dominant']}** | {t['roofline_fraction']:.3f} "
            f"| {r['useful_ratio']:.2f} | {note} |")
    return "\n".join(rows)


def _kind(shape):
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


if __name__ == "__main__":
    print("### Dry-run table\n")
    print(dryrun_table())
    print("\n### Roofline table\n")
    print(roofline_table())
