#!/usr/bin/env python
"""Compare two BENCH_*.json reports and flag perf/predictability
regressions (the bench-trajectory gate from ROADMAP).

  PYTHONPATH=src python scripts/bench_diff.py OLD.json NEW.json

Benchmarks are matched by name.  A row regresses when:

- ``us_per_call`` grows more than ``--rel-tol`` (relative) AND more
  than ``--abs-floor-us`` (absolute — micro-rows are noise-floored), or
- ``jitter.p99`` grows the same way (both reports must carry the
  jitter block), or
- ``jitter.cov`` grows more than ``--cov-tol`` relative plus
  ``--cov-abs`` absolute — the predictability gate: a speedup that
  fluctuates more is still a regression.

Exit codes: 0 = no regressions, 1 = regression(s), 2 = unreadable or
schema-invalid input.  Rows present in only one report are listed but
never fail the gate; differing environment fingerprints print a
warning (cross-machine numbers are not comparable).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

EXIT_OK, EXIT_REGRESSION, EXIT_INVALID = 0, 1, 2


def load_report(path: str) -> Optional[Dict[str, Any]]:
    """Load + schema-validate; returns None (with stderr noise) on any
    problem."""
    from repro.obs.report import validate_report
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
        return None
    errs = validate_report(doc)
    if errs:
        print(f"bench_diff: {path} is not a valid schema-v1 report:",
              file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        return None
    return doc


def _grew(old: float, new: float, rel_tol: float,
          abs_floor: float) -> bool:
    return new > old * (1.0 + rel_tol) and (new - old) > abs_floor


def compare(old: Dict[str, Any], new: Dict[str, Any], *,
            rel_tol: float, abs_floor_us: float, cov_tol: float,
            cov_abs: float) -> Tuple[List[str], List[str], List[str]]:
    """-> (regressions, improvements, notes), each human-readable."""
    old_by = {b["name"]: b for b in old["benchmarks"]}
    new_by = {b["name"]: b for b in new["benchmarks"]}
    regressions: List[str] = []
    improvements: List[str] = []
    notes: List[str] = []

    for name in sorted(set(old_by) & set(new_by)):
        o, n = old_by[name], new_by[name]
        ou, nu = float(o["us_per_call"]), float(n["us_per_call"])
        if _grew(ou, nu, rel_tol, abs_floor_us):
            regressions.append(
                f"{name}: us_per_call {ou:.1f} -> {nu:.1f} "
                f"(+{(nu / ou - 1) * 100:.0f}%)")
        elif nu < ou * (1.0 - rel_tol) and (ou - nu) > abs_floor_us:
            improvements.append(
                f"{name}: us_per_call {ou:.1f} -> {nu:.1f} "
                f"({(nu / ou - 1) * 100:.0f}%)")
        oj, nj = o.get("jitter"), n.get("jitter")
        if not (isinstance(oj, dict) and isinstance(nj, dict)):
            continue
        op99, np99 = float(oj["p99"]), float(nj["p99"])
        if _grew(op99, np99, rel_tol, abs_floor_us):
            regressions.append(
                f"{name}: jitter.p99 {op99:.1f} -> {np99:.1f} "
                f"(+{(np99 / op99 - 1) * 100:.0f}%)")
        ocov, ncov = float(oj["cov"]), float(nj["cov"])
        if ncov > ocov * (1.0 + cov_tol) + cov_abs:
            regressions.append(
                f"{name}: jitter.cov {ocov:.4f} -> {ncov:.4f} "
                "(predictability regression)")

    # asymmetric rows never gate — only the intersection is compared —
    # but each skipped name is surfaced so a silently dropped benchmark
    # can't masquerade as a clean diff
    for name in sorted(set(old_by) - set(new_by)):
        notes.append(f"{name}: skipped, only in old report")
    for name in sorted(set(new_by) - set(old_by)):
        notes.append(f"{name}: skipped, only in new report")
    return regressions, improvements, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json reports; non-zero exit on "
                    "speed or predictability regressions")
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--rel-tol", type=float, default=0.5,
                    help="relative us_per_call/p99 growth tolerated "
                         "(default 0.5 = +50%%; wall-clock rows are "
                         "noisy)")
    ap.add_argument("--abs-floor-us", type=float, default=50.0,
                    help="absolute growth (us) below which a row "
                         "never regresses")
    ap.add_argument("--cov-tol", type=float, default=0.5,
                    help="relative CoV growth tolerated")
    ap.add_argument("--cov-abs", type=float, default=0.02,
                    help="absolute CoV slack on top of --cov-tol")
    args = ap.parse_args(argv)

    old = load_report(args.old)
    new = load_report(args.new)
    if old is None or new is None:
        return EXIT_INVALID

    fp_keys = ("python", "platform", "machine", "jax", "numpy")
    ofp, nfp = old["hw_fingerprint"], new["hw_fingerprint"]
    drift = [k for k in fp_keys if ofp.get(k) != nfp.get(k)]
    if drift:
        print(f"WARNING: environment fingerprint differs on "
              f"{', '.join(drift)} — numbers may not be comparable",
              file=sys.stderr)

    regressions, improvements, notes = compare(
        old, new, rel_tol=args.rel_tol, abs_floor_us=args.abs_floor_us,
        cov_tol=args.cov_tol, cov_abs=args.cov_abs)

    for line in notes:
        print(f"warning: {line}")
    for line in improvements:
        print(f"improved: {line}")
    for line in regressions:
        print(f"REGRESSION: {line}")
    common = set(b["name"] for b in old["benchmarks"]) \
        & set(b["name"] for b in new["benchmarks"])
    print(f"bench_diff: {len(regressions)} regression(s), "
          f"{len(improvements)} improvement(s) across "
          f"{len(common)} common benchmarks")
    return EXIT_REGRESSION if regressions else EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
