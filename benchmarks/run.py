"""Benchmark harness — one module per paper table/figure plus the
TPU-side roofline/dry-run reports.  Prints ``name,us_per_call,derived``
CSV (assignment format; byte-stable across PRs).

  PYTHONPATH=src python -m benchmarks.run [--fast]

``--json PATH`` additionally writes a schema-versioned structured
report (repro.obs.report): the CSV fields plus jitter statistics for
the Fig. 4 fluctuation sweep and an environment fingerprint — the
machine-readable BENCH trajectory.  ``--only k1,k2`` restricts the run
to named suite entries (for tests/tooling; CSV format is unchanged).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def suite(fast: bool):
    """Ordered (key, thunk) benchmark table."""
    from benchmarks import (bench_beyond_paper, bench_dryrun_summary,
                            bench_fig3_roofline, bench_fig4_matmul,
                            bench_fig5_resources, bench_kernels,
                            bench_serve_steps, bench_table12_fmax,
                            bench_tpu_roofline)
    # jax-heavy suites go LAST: their measurements leave a large live
    # jax heap behind, and the pure-Python simulator suites slow down
    # measurably (GC pressure) when they run after them.
    return [
        ("table12", bench_table12_fmax.run),
        ("fig3", bench_fig3_roofline.run),
        ("fig4", lambda: bench_fig4_matmul.run(
            n_runs=10 if fast else 100)),
        ("fig5", bench_fig5_resources.run),
        ("beyond", bench_beyond_paper.run),
        ("tpu_roofline", bench_tpu_roofline.run),
        ("dryrun", bench_dryrun_summary.run),
        ("kernels", bench_kernels.run),
        ("serve_steps", bench_serve_steps.run),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer Fig.4 simulation runs")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a schema-versioned JSON report")
    ap.add_argument("--only", metavar="KEYS", default=None,
                    help="comma-separated suite keys (e.g. fig4,fig5)")
    args, _ = ap.parse_known_args(argv)

    entries = suite(args.fast)
    if args.only:
        want = {k.strip() for k in args.only.split(",") if k.strip()}
        unknown = want - {k for k, _ in entries}
        if unknown:
            ap.error(f"unknown suite keys: {sorted(unknown)} "
                     f"(have {[k for k, _ in entries]})")
        entries = [(k, fn) for k, fn in entries if k in want]

    rows = []
    for _, fn in entries:
        rows += fn()

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.2f},\"{r['derived']}\"")

    if args.json:
        import json

        from repro.obs.report import make_report, validate_report
        report = make_report(rows, fast=args.fast)
        errs = validate_report(report)
        assert not errs, errs
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2, sort_keys=False)
        print(f"json report: {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
