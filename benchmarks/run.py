"""Benchmark harness — one module per paper table/figure plus the
TPU-side roofline/dry-run reports.  Prints ``name,us_per_call,derived``
CSV (assignment format).

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer Fig.4 simulation runs")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_beyond_paper, bench_dryrun_summary,
                            bench_fig3_roofline, bench_fig4_matmul,
                            bench_fig5_resources, bench_kernels,
                            bench_table12_fmax, bench_tpu_roofline)

    rows = []
    rows += bench_table12_fmax.run()
    rows += bench_fig3_roofline.run()
    rows += bench_fig4_matmul.run(n_runs=10 if args.fast else 100)
    rows += bench_fig5_resources.run()
    rows += bench_kernels.run()
    rows += bench_beyond_paper.run()
    rows += bench_tpu_roofline.run()
    rows += bench_dryrun_summary.run()

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.2f},\"{r['derived']}\"")


if __name__ == "__main__":
    main()
