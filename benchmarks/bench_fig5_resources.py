"""Paper Fig. 5: FPGA resource utilization — per-variant totals and the
dual-core per-component breakdown."""
import time

from repro.configs.multivic_paper import DUAL, EVAL_CONFIGS
from repro.core.resources import component_resources, total_resources


def run():
    rows = []
    for hw in EVAL_CONFIGS:
        t0 = time.time()
        t = total_resources(hw)
        rows.append({
            "name": f"fig5a/{hw.name}",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": (f"lut={t['lut']:.0f};ff={t['ff']:.0f};"
                        f"bram={t['bram']:.0f};dsp={t['dsp']:.0f}"),
        })
    t0 = time.time()
    comps = component_resources(DUAL)
    dt = (time.time() - t0) * 1e6
    for cname, c in comps.items():
        rows.append({
            "name": f"fig5b/dual/{cname}",
            "us_per_call": dt / len(comps),
            "derived": (f"lut={c['lut']:.0f};ff={c['ff']:.0f};"
                        f"bram={c['bram']:.0f};dsp={c['dsp']:.0f}"),
        })
    return rows
