"""Multi-pod dry-run summary: per-cell compile status, per-device
memory, and collective inventory from experiments/dryrun/."""
import json
import pathlib
import time

DRY = pathlib.Path("experiments/dryrun")


def run():
    rows = []
    if not DRY.exists():
        return [{"name": "dryrun/missing", "us_per_call": 0,
                 "derived": "run: python -m repro.launch.dryrun --all"}]
    for f in sorted(DRY.glob("*.json")):
        t0 = time.time()
        r = json.loads(f.read_text())
        m = r.get("memory", {})
        colls = r.get("collectives", {})
        cstr = ",".join(f"{k}:{v['count']}" for k, v in
                        sorted(colls.items()))
        rows.append({
            "name": f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": (
                f"status={r['status']};"
                f"args_gb={m.get('argument_bytes', 0)/1e9:.2f};"
                f"temp_gb={m.get('temp_bytes', 0)/1e9:.2f};"
                f"collectives={cstr or 'none'}"),
        })
    return rows
