"""Paper Fig. 3: theoretical roofline per configuration — compute
ceiling shared with the Fast baseline, SPM-bandwidth boundary shifting
with core count."""
import time

from repro.configs.multivic_paper import EVAL_CONFIGS
from repro.core.roofline import attainable_gflops, config_roofline


def run():
    rows = []
    for hw in EVAL_CONFIGS:
        t0 = time.time()
        r = config_roofline(hw)
        # attainable perf at the matmul benchmark's arithmetic intensity
        # (~2 FLOPs per 8 bytes from SPM for fp32 dot products)
        ai = 0.25
        att = attainable_gflops(hw, ai)
        rows.append({
            "name": f"fig3/{hw.name}",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": (
                f"peak_gflops={r['peak_gflops']:.2f};"
                f"spm_bw_gbs={r['spm_bw_gbs']:.2f};"
                f"dram_bw_gbs={r['dram_bw_gbs']:.2f};"
                f"ridge_spm={r['ridge_spm']:.2f};"
                f"attainable@0.25={att:.2f}"),
        })
    return rows
