"""End-to-end serving-step benchmark: tuned vs default model plan.

The serving analogue of bench_kernels: each problem in
``SERVE_PROBLEMS`` is timed twice as a full prefill + decode pass —
once under the shape-safe default serving plan and once under the
autotuned plan (repro.tuning.tune_model — measured on a cold plan
cache, reused with zero measurements on a warm one).  Both sides run
AOT-compiled step programs (compilation never lands in a sample), so
the CoV/p99 speak for the plan, not the compiler.

Two rows per problem so the trajectory gate (scripts/bench_diff.py)
tracks each side independently:

  serve/<arch>_decode_default   us_per_call = default us/token
  serve/<arch>_decode_tuned     us_per_call = tuned us/token

``derived`` carries both plans, the plan source, and the plan-derived
TPU WCET bound per decode step (core.tpu_mapping.serve_step_schedule —
the same number the serve banner prints, because it is built from the
same plan).
"""
from benchmarks.bench_kernels import REPS, WARMUP

# Small enough to tune (a handful of end-to-end passes each) inside a
# benchmark run, big enough that chunking and loop structure matter.
SERVE_PROBLEMS = [
    ("qwen2-0.5b", dict(batch=2, prompt_len=64, gen=8,
                        layers=2, d_model=128, vocab=512)),
]


def _wcet_us(cfg, problem, plan) -> float:
    from repro.core.tpu_mapping import serve_step_schedule, tpu_wcet
    from repro.models.lm import param_count
    sched = serve_step_schedule(problem.batch, cfg.d_model,
                                param_count(cfg), plan=plan)
    return tpu_wcet(sched) * 1e6


def run():
    from repro.tuning import (ModelProblem, default_model_plan,
                              make_serve_runner, measure_callable,
                              plan_sig, problem_config, tune_model,
                              us_per_token)
    rows = []
    for arch, kw in SERVE_PROBLEMS:
        problem = ModelProblem(arch, **kw)
        cfg = problem_config(problem)
        default_plan = default_model_plan(cfg, problem)
        res = tune_model(problem, reps=REPS, warmup=WARMUP)
        if res.source == "measured":
            d_stats, t_stats = res.default_stats, res.stats
            if res.plan == default_plan:
                t_stats = d_stats   # identical program: one measurement
        else:
            # warm cache: the tuner performed zero measurements, so
            # time both sides here (default first, mirroring the cold
            # path's measurement order)
            d_stats = measure_callable(
                make_serve_runner(cfg, problem, default_plan),
                reps=REPS, warmup=WARMUP)
            t_stats = d_stats if res.plan == default_plan \
                else measure_callable(
                    make_serve_runner(cfg, problem, res.plan),
                    reps=REPS, warmup=WARMUP)
        shared = (f"default_plan={plan_sig(default_plan)};"
                  f"tuned_plan={plan_sig(res.plan)};"
                  f"plan_source={res.source};"
                  f"gen={problem.gen};"
                  f"default_us_tok={us_per_token(d_stats, problem):.1f};"
                  f"tuned_us_tok={us_per_token(t_stats, problem):.1f};"
                  f"default_cov={d_stats.cov:.4f};"
                  f"tuned_cov={t_stats.cov:.4f};")
        for tag, plan, stats in (("default", default_plan, d_stats),
                                 ("tuned", res.plan, t_stats)):
            rows.append({
                "name": f"serve/{arch}_decode_{tag}",
                "us_per_call": us_per_token(stats, problem),
                "derived": (shared +
                            f"tpu_wcet_step_us="
                            f"{_wcet_us(cfg, problem, plan):.3f}"),
                "jitter": stats.as_dict()})
    return rows
