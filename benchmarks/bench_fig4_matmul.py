"""Paper Fig. 4: the matmul benchmark executed 100x per configuration —
median execution cycles and standard deviation, plus the paper anchors.

Each row also carries the full fluctuation summary (``jitter`` key:
CoV, p99, spread, WCET margin — repro.obs.jitter) consumed by the
``--json`` report sink; the CSV ``derived`` payload is unchanged.
"""
import time

from repro.configs.multivic_paper import (EVAL_CONFIGS,
                                          PAPER_MEDIAN_CYCLES,
                                          PAPER_SECONDS)
from repro.core.scheduler import MatmulProblem, build_matmul_schedule
from repro.core.simulator import sweep_cycles
from repro.core.wcet import wcet
from repro.obs.jitter import jitter_stats


def run(n_runs: int = 100):
    rows = []
    for hw in EVAL_CONFIGS:
        t0 = time.time()
        sched = build_matmul_schedule(hw, MatmulProblem())
        cycles = sweep_cycles(sched, hw, n_runs=n_runs)
        bound = wcet(sched, hw)
        stats = jitter_stats(cycles, wcet_bound=bound)
        secs = stats.median / hw.fmax_hz
        target = PAPER_MEDIAN_CYCLES.get(hw.name)
        err = (stats.median / target - 1) if target else None
        rows.append({
            "name": f"fig4/{hw.name}",
            "us_per_call": (time.time() - t0) * 1e6 / n_runs,
            "derived": (
                f"median_cycles={stats.median:.0f};std={stats.std:.0f};"
                f"sec@fmax={secs:.3f};wcet={bound:.0f}"
                + (f";paper={target};err={err:+.4%}" if target else "")
                + (f";paper_sec={PAPER_SECONDS[hw.name]}"
                   if hw.name in PAPER_SECONDS else "")),
            "jitter": stats.as_dict(),
        })
    return rows
