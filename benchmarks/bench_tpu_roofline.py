"""TPU roofline per (arch x shape): reads the dry-run + roofline sweep
artifacts (experiments/) and reports the three terms, dominant
bottleneck, and the MODEL_FLOPS ratio for every cell (EXPERIMENTS.md
§Roofline is generated from the same records)."""
import json
import pathlib
import time

ROOF = pathlib.Path("experiments/roofline")


def run():
    rows = []
    if not ROOF.exists():
        return [{"name": "tpu_roofline/missing", "us_per_call": 0,
                 "derived": "run: python -m repro.launch.roofline_run --all"}]
    for f in sorted(ROOF.glob("*.json")):
        t0 = time.time()
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        t = r["terms"]
        rows.append({
            "name": f"tpu_roofline/{r['arch']}/{r['shape']}",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": (
                f"compute_s={t['compute_s']:.4f};"
                f"memory_s={t['memory_s']:.4f};"
                f"collective_s={t['collective_s']:.4f};"
                f"dominant={t['dominant']};"
                f"roofline_frac={t['roofline_fraction']:.3f};"
                f"useful_flops_ratio={r['useful_ratio']:.3f}"),
        })
    return rows
