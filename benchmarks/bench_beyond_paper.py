"""Beyond-paper studies on the validated models:

1. 32-core extrapolation — the paper stops at 16 cores (routing
   congestion); the fitted F_max model + calibrated timing model
   predict whether 32 would ever pay off.
2. Whole-network time-triggered execution (paper §4.3 future work):
   event-driven vs time-triggered vs WCET for a 4-layer MLP, showing
   the jitter collapse the paper argues for.
"""
import time

from repro.configs.multivic_paper import (HEXADECA, MultiVicConfig, OCTA,
                                          VicunaConfig, KIB)
from repro.core.fmax import predict_fmax_mhz
from repro.core.network_scheduler import (build_network_schedule, mlp,
                                          release_times,
                                          simulate_time_triggered,
                                          tt_jitter_bound)
from repro.core.scheduler import MatmulProblem, build_matmul_schedule
from repro.core.simulator import run_many, simulate
from repro.core.wcet import wcet

TRIACONTADI = MultiVicConfig(
    "triacontadi-32", 32, VicunaConfig(64, 32), 32 * KIB, 16 * KIB,
    fmax_hz=0.0)   # F_max predicted, not measured


def run():
    rows = []

    # --- 32-core extrapolation -------------------------------------
    t0 = time.time()
    # 32 KiB SPMs force single-row A transfers (the scaling squeeze)
    sched = build_matmul_schedule(TRIACONTADI, MatmulProblem(),
                                  rows_per_transfer=1)
    stats = run_many(sched, TRIACONTADI, n_runs=5)
    octa_secs = 4.34
    # two-sided bound: the congestion model extrapolated to 66 crossbar
    # ports collapses F_max entirely (pessimistic — beyond the fitted
    # domain); even granting hexadeca's measured 118 MHz (optimistic),
    # the gain over Octa is <12% for 4x the cores.
    f_pess = max(1.0, predict_fmax_mhz(TRIACONTADI)) * 1e6
    f_opt = 118e6
    rows.append({
        "name": "beyond/triacontadi-32",
        "us_per_call": (time.time() - t0) * 1e6,
        "derived": (
            f"median_cycles={stats['median']:.0f};"
            f"sec@optimistic118MHz={stats['median']/f_opt:.2f}"
            f"(vs octa {octa_secs});"
            f"sec@congestion_model={stats['median']/f_pess:.1f};"
            f"verdict=32 cores forclosed by the paper's congestion "
            f"trend (<=12% best-case gain for 2x cores)"),
    })

    # --- time-triggered whole network --------------------------------
    for hw in (OCTA, HEXADECA):
        t0 = time.time()
        net = mlp(256, [1024, 512, 512, 256, 64])
        sched = build_network_schedule(hw, net)
        rel = release_times(sched, hw)
        ev = [simulate(sched, hw, seed=s).total_cycles for s in range(5)]
        tt = [simulate_time_triggered(sched, hw, rel, seed=s)[0]
              .total_cycles for s in range(5)]
        w = wcet(sched, hw)
        rows.append({
            "name": f"beyond/tt_mlp/{hw.name}",
            "us_per_call": (time.time() - t0) * 1e6 / 10,
            "derived": (
                f"event_med={sorted(ev)[2]:.0f};event_spread="
                f"{max(ev)-min(ev):.0f};tt_med={sorted(tt)[2]:.0f};"
                f"tt_spread={max(tt)-min(tt):.0f}"
                f"(bound {tt_jitter_bound():.0f});wcet={w:.0f};"
                f"tt_overhead={(sorted(tt)[2]/sorted(ev)[2]-1)*100:.2f}%"),
        })
    return rows
