"""Paper Tables 1-2: configuration variants and maximum achievable
clock frequencies — measured (published) vs our fitted critical-path /
routing-congestion model."""
import time

from repro.configs.multivic_paper import PAPER_CONFIGS
from repro.core.fmax import model_table


def run():
    rows = []
    t0 = time.time()
    table = model_table()
    dt = (time.time() - t0) * 1e6 / max(1, len(table))
    for (name, meas, pred, err), hw in zip(table, PAPER_CONFIGS):
        rows.append({
            "name": f"table12/{name}",
            "us_per_call": dt,
            "derived": (
                f"workers={hw.num_worker_cores};vreg={hw.vicuna.vreg_bits};"
                f"mul={hw.vicuna.mul_width_bits};"
                f"spm_kib={hw.data_spm_bytes // 1024};"
                f"fmax_meas={meas:.0f}MHz;fmax_model={pred:.1f}MHz;"
                f"err={err:+.2%}"),
        })
    return rows
