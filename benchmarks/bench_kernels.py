"""Kernel micro-benchmarks: interpret-mode CPU timing (correctness
path) + the TPU-target analytic time from the static-schedule WCET
model (what the BlockSpec schedule promises on the real part)."""
import time

import jax
import jax.numpy as jnp

from repro.core.tpu_mapping import (tpu_matmul_schedule, tpu_steady_state,
                                    tpu_wcet)


def _time(fn, *args, reps=3):
    fn(*args)                      # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # spm_matmul
    from repro.kernels.spm_matmul.ops import matmul
    m = k = n = 512
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(key, (k, n), jnp.float32)
    us = _time(lambda x, y: matmul(x, y, bm=256, bn=256), a, b)
    sched = tpu_matmul_schedule(m, k, n, tile_m=256, tile_n=256,
                                elem_bytes=4)
    rows.append({
        "name": "kernel/spm_matmul_512",
        "us_per_call": us,
        "derived": (f"tpu_wcet_us={tpu_wcet(sched)*1e6:.2f};"
                    f"tpu_steady_us={tpu_steady_state(sched)*1e6:.2f};"
                    f"interpret=True"),
    })

    # flash attention
    from repro.kernels.flash_attention.ops import attention
    B, S, H, KV, D = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    kk = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    us = _time(lambda *xs: attention(*xs, bq=128, bk=128), q, kk, v)
    flops = 4 * B * H * S * S * D / 2          # causal
    rows.append({
        "name": "kernel/flash_attn_256",
        "us_per_call": us,
        "derived": (f"tpu_compute_us={flops/197e12*1e6:.3f};"
                    f"interpret=True"),
    })

    # wkv6
    from repro.kernels.wkv6.ops import wkv
    B, S, H, K = 1, 256, 2, 64
    r = jax.random.normal(key, (B, S, H, K)) * 0.5
    kx = jax.random.normal(key, (B, S, H, K)) * 0.5
    vx = jax.random.normal(key, (B, S, H, K)) * 0.5
    w = -jnp.exp(jax.random.normal(key, (B, S, H, K)) * 0.5 - 2)
    u = jax.random.normal(key, (H, K)) * 0.3
    us = _time(lambda *xs: wkv(*xs, chunk=64), r, kx, vx, w, u)
    chunk_flops = B * H * (S / 64) * (64 * 64 * K * 3 + 64 * K * K * 2)
    rows.append({
        "name": "kernel/wkv6_256",
        "us_per_call": us,
        "derived": (f"tpu_compute_us={chunk_flops/197e12*1e6:.4f};"
                    f"interpret=True"),
    })
    return rows
