"""Kernel micro-benchmarks with a tuned-vs-default comparison.

Every registered kernel (repro.kernels.KERNEL_REGISTRY) is timed twice
on its benchmark problem (repro.tuning.DEFAULT_PROBLEMS): once with
the shape-safe default block plan and once with the autotuned plan
(repro.tuning.tune — measured on a cold plan cache, reused with zero
measurements on a warm one).  The row's ``us_per_call`` is the tuned
time; ``derived`` carries both sides (``default_us``/``tuned_us``,
``default_cov``/``tuned_cov``) plus the winning plan and the
TPU-target analytic bound from the static-schedule WCET model, and the
``jitter`` block holds the tuned plan's full fluctuation stats.

Interpret-mode CPU timing (the correctness path): absolute numbers are
not TPU numbers, but the tuned-vs-default delta and the CoV are what
the bench trajectory gates on (scripts/bench_diff.py).
"""
from repro.core.tpu_mapping import (tpu_matmul_schedule, tpu_steady_state,
                                    tpu_wcet)

# CoV needs a real sample: n=5 gives ~±0.02 noise on the estimate,
# which would swamp the tuned-vs-default predictability comparison.
REPS = 12
WARMUP = 2


def _compare(kernel, problem):
    """(default_plan, default_stats, tune_result, tuned_stats)."""
    from repro.tuning import (defaults_for, make_runner,
                              measure_callable, tune)
    default_plan = defaults_for(kernel, problem)
    res = tune(kernel, problem, reps=REPS, warmup=WARMUP)
    d_stats = measure_callable(
        make_runner(kernel, problem, default_plan),
        reps=REPS, warmup=WARMUP)
    if res.plan == default_plan:
        # identical program: re-measuring would only add noise
        t_stats = d_stats
    else:
        t_stats = measure_callable(
            make_runner(kernel, problem, res.plan),
            reps=REPS, warmup=WARMUP)
    return default_plan, d_stats, res, t_stats


def _row(name, extra, default_plan, d_stats, res, t_stats):
    from repro.tuning import plan_sig
    derived = (f"{extra}"
               f"default_plan={plan_sig(default_plan)};"
               f"tuned_plan={plan_sig(res.plan)};"
               f"plan_source={res.source};"
               f"default_us={d_stats.mean:.1f};"
               f"tuned_us={t_stats.mean:.1f};"
               f"default_cov={d_stats.cov:.4f};"
               f"tuned_cov={t_stats.cov:.4f};"
               f"interpret=True")
    return {"name": name, "us_per_call": t_stats.mean,
            "derived": derived, "jitter": t_stats.as_dict()}


def run():
    from repro.tuning import DEFAULT_PROBLEMS
    rows = []

    # spm_matmul — static-schedule WCET bound built from the TUNED tile
    # plan, so the analytic promise tracks what actually runs.
    p = DEFAULT_PROBLEMS["spm_matmul"]
    default_plan, d_stats, res, t_stats = _compare("spm_matmul", p)
    sched = tpu_matmul_schedule(
        p.m, p.k, p.n, tile_m=min(res.plan["bm"], p.m),
        tile_n=min(res.plan["bn"], p.n), elem_bytes=4)
    extra = (f"tpu_wcet_us={tpu_wcet(sched)*1e6:.2f};"
             f"tpu_steady_us={tpu_steady_state(sched)*1e6:.2f};")
    rows.append(_row(f"kernel/spm_matmul_{p.m}", extra,
                     default_plan, d_stats, res, t_stats))

    # flash attention
    a = DEFAULT_PROBLEMS["flash_attention"]
    default_plan, d_stats, res, t_stats = _compare("flash_attention", a)
    flops = 4 * a.batch * a.heads * a.seq_q * a.seq_k * a.head_dim / 2
    extra = f"tpu_compute_us={flops/197e12*1e6:.3f};"
    rows.append(_row(f"kernel/flash_attn_{a.seq_q}", extra,
                     default_plan, d_stats, res, t_stats))

    # wkv6
    w = DEFAULT_PROBLEMS["wkv6"]
    default_plan, d_stats, res, t_stats = _compare("wkv6", w)
    L = res.plan["chunk"]
    chunk_flops = w.batch * w.heads * (w.seq / L) \
        * (L * L * w.key_dim * 3 + L * w.key_dim * w.key_dim * 2)
    extra = f"tpu_compute_us={chunk_flops/197e12*1e6:.4f};"
    rows.append(_row(f"kernel/wkv6_{w.seq}", extra,
                     default_plan, d_stats, res, t_stats))
    return rows
