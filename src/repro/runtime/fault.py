"""Fault-tolerance building blocks for 1000+-node operation.

PreemptionGuard   — SIGTERM/SIGINT-aware flag the train loop polls; on
                    preemption the loop checkpoints and exits cleanly.
StragglerMonitor  — EWMA step-time tracker; flags steps slower than
                    k x the trailing mean (the time-predictability lens
                    applied to the datacenter: with a static schedule a
                    slow step is an anomaly worth acting on, exactly the
                    paper's jitter argument).
elastic_remesh_plan — given a new device count after failures, choose
                    the nearest valid (data, model) mesh and report how
                    batch/shardings change; CheckpointManager.restore
                    (unsharded leaves) completes the elastic restart.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:       # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def trigger_for_test(self):
        self._requested = True


@dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x trailing mean
    alpha: float = 0.1              # EWMA factor
    trace: Optional[Any] = None     # obs.TraceRecorder: step_s counter
    _mean: Optional[float] = None   # + straggler instants
    events: List[Tuple[int, float, float]] = field(default_factory=list)
    _t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> bool:
        if self._t0 is None:      # no matching step_start(): nothing to
            return False          # measure — don't poison the EWMA
        dt = time.monotonic() - self._t0
        self._t0 = None
        is_straggler = (self._mean is not None
                        and dt > self.threshold * self._mean)
        if is_straggler:
            self.events.append((step, dt, self._mean))
        if self.trace is not None:
            self.trace.counter("step_s", dt)
            if is_straggler:
                self.trace.instant("straggler", track="trainer",
                                   step=step, step_s=dt,
                                   trailing_mean_s=self._mean)
        self._mean = (dt if self._mean is None
                      else (1 - self.alpha) * self._mean + self.alpha * dt)
        return is_straggler

    @property
    def mean_step_s(self) -> Optional[float]:
        return self._mean


def elastic_remesh_plan(n_devices: int, model_parallel: int = 16,
                        min_data: int = 1) -> dict:
    """Largest (data, model) mesh usable with n_devices survivors.

    Keeps the model axis fixed (weight shards must still fit) and
    shrinks the data axis — surviving hosts re-shard via checkpoint
    restore; the global batch is kept by raising per-device batch or
    gradient accumulation (reported in the plan).

    Invariants (chaos-tested): devices_used + devices_idle ==
    n_devices and grad_accum_factor >= 1, for any n_devices >= 0."""
    if n_devices <= 0:            # total outage: nothing schedulable
        return {"data": 0, "model": 0,
                "devices_used": 0, "devices_idle": n_devices,
                "grad_accum_factor": 1}
    if n_devices < model_parallel:
        # degrade model parallelism to the largest power-of-two <= n
        mp = 1
        while mp * 2 <= n_devices:
            mp *= 2
        model_parallel = mp
    data = max(min_data, n_devices // model_parallel)
    if data * model_parallel > n_devices:
        raise ValueError(
            f"min_data={min_data} needs {data * model_parallel} devices "
            f"but only {n_devices} survive")
    used = data * model_parallel
    return {
        "data": data, "model": model_parallel,
        "devices_used": used, "devices_idle": n_devices - used,
        "grad_accum_factor": max(1, 16 // data),
    }
