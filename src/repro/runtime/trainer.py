"""The fault-tolerant training runtime.

Wires together: model (models/), optimizer (optim/), data (data/),
checkpointing (checkpoint/), the fault handlers (runtime/fault.py) and
the chaos harness (resilience/chaos.py).  Designed so a
preempted/crashed job relaunched with `Trainer.run()` resumes
bit-exact: deterministic data (pure function of step), full
(params, opt_state, step) in the checkpoint, periodic + preemption
saves, and a non-finite-loss guard that *retries* a poisoned step
instead of skipping its batch — a transient NaN therefore changes
nothing about the final parameters, which is what lets the chaos soak
test demand bit-exact equality against an undisturbed run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.models import lm as lm_mod
from repro.optim.adamw import adamw_init, make_train_step
from repro.resilience.chaos import (FaultPlan, TransientIOFault,
                                    corrupt_checkpoint,
                                    corrupt_plan_cache)
from repro.runtime.fault import PreemptionGuard, StragglerMonitor


class NonFiniteLossError(RuntimeError):
    """K consecutive non-finite losses: the divergence is persistent,
    not transient — aborting beats looping forever on a poisoned
    step."""


@dataclass
class TrainerState:
    params: Any
    opt_state: Any
    step: int = 0


@dataclass
class Trainer:
    cfg: ModelConfig
    tcfg: TrainConfig
    dcfg: DataConfig
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    opts: lm_mod.RunOptions = field(default_factory=lm_mod.RunOptions)
    log_every: int = 10
    on_metrics: Optional[Callable[[int, Dict], None]] = None
    trace: Optional[Any] = None     # obs.TraceRecorder (wall-clock us)
    chaos: Optional[FaultPlan] = None   # resilience: fault injection
    max_nonfinite: int = 3          # consecutive bad steps -> abort
    deadline: Optional[Any] = None  # resilience.DeadlineMonitor: each
    # training step walks the same record->warn ladder as serving
    # (train never sheds; the overrun summary is the deliverable)

    def __post_init__(self):
        self.dataset = SyntheticLMDataset(self.dcfg)
        self.ckpt = (CheckpointManager(self.ckpt_dir, trace=self.trace)
                     if self.ckpt_dir else None)
        self.guard = PreemptionGuard()
        self.straggler = StragglerMonitor(trace=self.trace)
        if self.chaos is not None and self.chaos.trace is None:
            self.chaos.trace = self.trace
        self.nonfinite_steps: List[int] = []
        self._step_fn = jax.jit(
            make_train_step(self.cfg, self.tcfg, self.opts),
            donate_argnums=(0, 1))

    # ------------------------------------------------------------ state

    def init_state(self, seed: int = 0) -> TrainerState:
        params = lm_mod.init_params(self.cfg, jax.random.PRNGKey(seed))
        return TrainerState(params, adamw_init(params), 0)

    def restore_or_init(self) -> TrainerState:
        state = self.init_state(self.tcfg.seed)
        if self.ckpt and self.ckpt.latest_step() is not None:
            tree = {"params": state.params, "opt": state.opt_state}
            restored, step = self.ckpt.restore(tree)
            return TrainerState(restored["params"], restored["opt"], step)
        return state

    # ------------------------------------------------------------ chaos

    def _apply_faults(self, step: int) -> float:
        """Fire the fault plan's injections for this step; returns the
        loss_scale to feed the train step (NaN for a poisoned step)."""
        scale = 1.0
        for f in self.chaos.take(step):
            if f.kind == "nan_loss":
                scale = float("nan")
            elif f.kind == "preempt":
                self.guard.trigger_for_test()
            elif f.kind == "straggler":
                time.sleep(f.duration_s)
            elif f.kind == "io_error" and self.ckpt:
                self.ckpt.fault_hook = TransientIOFault(count=f.count)
            elif f.kind == "ckpt_corrupt" and self.ckpt:
                self.ckpt.wait()    # damage a *published* checkpoint
                corrupt_checkpoint(self.ckpt.dir,
                                   mode=f.mode or "array",
                                   rng=self.chaos.rng)
            elif f.kind == "cache_corrupt":
                import os

                from repro.tuning.plan_cache import (DEFAULT_CACHE_PATH,
                                                     CACHE_PATH_ENV)
                corrupt_plan_cache(
                    os.environ.get(CACHE_PATH_ENV, DEFAULT_CACHE_PATH),
                    mode=f.mode or "garbage")
        return scale

    # -------------------------------------------------------------- run

    def run(self, num_steps: int) -> Dict[str, List[float]]:
        state = self.restore_or_init()
        history: Dict[str, List[float]] = {"loss": [], "step_s": []}
        t_wall = time.monotonic()
        consecutive_nonfinite = 0
        while state.step < num_steps:
            scale = (self._apply_faults(state.step)
                     if self.chaos is not None else 1.0)
            batch = self.dataset.batch_at(state.step)
            self.straggler.step_start()
            t_step = time.monotonic()
            if self.trace is not None:
                self.trace.begin(f"step{state.step}", track="trainer",
                                 cat="train_step", step=state.step)
            params, opt, metrics = self._step_fn(
                state.params, state.opt_state, batch, scale)
            loss = float(metrics["loss"])   # blocks on device results
            finite = bool(metrics.get("finite", True))
            if self.trace is not None:
                self.trace.end("trainer")
                self.trace.counter("loss", loss)
            if not finite:
                # update was discarded in-step; retry the same step —
                # the batch is a pure function of the step counter, so
                # a transient fault leaves the trajectory untouched
                consecutive_nonfinite += 1
                self.nonfinite_steps.append(state.step)
                state = TrainerState(params, opt, state.step)
                self.straggler.step_end(state.step)
                if self.trace is not None:
                    self.trace.instant(
                        "nonfinite_skipped", track="trainer",
                        step=state.step, loss=loss,
                        consecutive=consecutive_nonfinite)
                if consecutive_nonfinite >= self.max_nonfinite:
                    raise NonFiniteLossError(
                        f"{consecutive_nonfinite} consecutive "
                        f"non-finite losses at step {state.step}")
                continue
            consecutive_nonfinite = 0
            dt_step = time.monotonic() - t_step
            state = TrainerState(params, opt, state.step + 1)
            slow = self.straggler.step_end(state.step)
            # deadline ladder (skip the first step: it pays compile).
            # training has no batch to shed, so "shed" only escalates
            # the message — the summary is the structured deliverable
            if self.deadline is not None and state.step > 1:
                action = self.deadline.observe(state.step, dt_step)
                if action in ("warn", "shed"):
                    print(f"deadline overrun at step {state.step}: "
                          f"{dt_step * 1e3:.2f} ms > "
                          f"{self.deadline.deadline_s * 1e3:.2f} ms"
                          + (" [persistent]" if action == "shed"
                             else ""))
            history["loss"].append(loss)
            history["step_s"].append(
                self.straggler.mean_step_s or 0.0)
            if self.on_metrics:
                self.on_metrics(state.step, metrics)
            if self.log_every and state.step % self.log_every == 0:
                print(f"step {state.step:5d} loss {loss:.4f} "
                      f"mean_step {self.straggler.mean_step_s:.3f}s"
                      + (" [STRAGGLER]" if slow else ""))
            if self.ckpt and (state.step % self.ckpt_every == 0
                              or self.guard.preempted):
                self.ckpt.save(state.step,
                               {"params": state.params,
                                "opt": state.opt_state},
                               blocking=self.guard.preempted)
            if self.guard.preempted:
                print(f"preempted at step {state.step}; "
                      f"checkpoint saved, exiting cleanly")
                break
        if self.ckpt:
            self.ckpt.save(state.step, {"params": state.params,
                                        "opt": state.opt_state})
            self.ckpt.wait()
        history["wall_s"] = [time.monotonic() - t_wall]
        self.final_state = state
        return history
