from repro.runtime.trainer import Trainer, TrainerState
from repro.runtime.fault import (PreemptionGuard, StragglerMonitor,
                                 elastic_remesh_plan)

__all__ = ["Trainer", "TrainerState", "PreemptionGuard",
           "StragglerMonitor", "elastic_remesh_plan"]
