from repro.models.lm import (RunOptions, cache_spec, compute_logits,
                             decode_step, forward_hidden, init_cache,
                             init_params, lm_loss, model_spec, prefill,
                             train_loss)

__all__ = ["RunOptions", "cache_spec", "compute_logits", "decode_step",
           "forward_hidden", "init_cache", "init_params", "lm_loss",
           "model_spec", "prefill", "train_loss"]
