"""Feed-forward layers: dense (SwiGLU / GeGLU / GELU / squared-ReLU) and
capacity-factor mixture-of-experts.

MoE uses GShard-style *static-shape* dispatch: tokens are grouped, each
expert accepts at most ``capacity`` tokens per group, overflow tokens are
dropped (their residual passes through).  This is the MoE that satisfies
the paper's static-scheduling requirement: the compile-time schedule must
not depend on input data, so the "additional assumptions ... during
scheduling" (paper §3) become the capacity factor.  Experts are sharded
on the ``model`` mesh axis (expert parallelism); the dispatch/combine
einsums lower to all-to-all-like collectives under GSPMD.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import activate, is_gated
from repro.models.spec import Par


# ---------------------------------------------------------------------------
# dense FFN


def dense_ffn_spec(d_model: int, d_ff: int, activation: str,
                   dtype: str) -> dict:
    p = {
        "w_gate": Par((d_model, d_ff), ("embed", "ffn"), init="scaled",
                      dtype=dtype),
        "w_down": Par((d_ff, d_model), ("ffn", "embed"), init="scaled",
                      dtype=dtype),
    }
    if is_gated(activation):
        p["w_up"] = Par((d_model, d_ff), ("embed", "ffn"), init="scaled",
                        dtype=dtype)
    return p


def dense_ffn(p: dict, x: jax.Array, activation: str) -> jax.Array:
    hg = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    hu = jnp.einsum("bsd,df->bsf", x, p["w_up"]) if "w_up" in p else None
    h = activate(hg, hu, activation)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# mixture of experts (capacity-factor, static shapes)


def moe_spec(d_model: int, m: MoEConfig, activation: str,
             dtype: str) -> dict:
    E, f = m.num_experts, m.expert_ff
    p = {
        "router": Par((d_model, E), ("embed", None), init="scaled",
                      dtype="float32"),
        "we_gate": Par((E, d_model, f), ("experts", "expert_ff", None),
                       init="scaled", dtype=dtype),
        "we_down": Par((E, f, d_model), ("experts", None, "expert_ff"),
                       init="scaled", dtype=dtype),
    }
    if is_gated(activation):
        p["we_up"] = Par((E, d_model, f), ("experts", "expert_ff", None),
                         init="scaled", dtype=dtype)
    if m.shared_expert_ff:
        p["shared"] = dense_ffn_spec(d_model, m.shared_expert_ff, activation,
                                     dtype)
    return p


def _topk_dispatch(gates: jax.Array, top_k: int, capacity: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Build combine [G,S,E,C] (fp32 weights) and dispatch (same support,
    value 1.0) from router probabilities ``gates`` [G,S,E].

    Classic GShard position assignment: experts fill in slot order; a
    token whose expert is full in slot j is dropped for that slot.
    """
    G, S, E = gates.shape
    top_vals, top_idx = jax.lax.top_k(gates, top_k)       # [G,S,K]
    counts = jnp.zeros((G, E), jnp.int32)
    combine = jnp.zeros((G, S, E, capacity), jnp.float32)
    for j in range(top_k):
        oh = jax.nn.one_hot(top_idx[..., j], E, dtype=jnp.int32)  # [G,S,E]
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]    # [G,S,E]
        pos_j = jnp.sum(pos * oh, axis=-1)                        # [G,S]
        keep = pos_j < capacity
        counts = counts + jnp.sum(oh, axis=1)
        pos_oh = jax.nn.one_hot(pos_j, capacity, dtype=jnp.float32)
        w = jnp.where(keep, top_vals[..., j], 0.0)
        combine = combine + (w[..., None, None]
                             * oh.astype(jnp.float32)[..., None]
                             * pos_oh[..., None, :])
    dispatch = (combine > 0).astype(gates.dtype)
    return combine, dispatch


def _gather_dispatch(xg, gates, m: MoEConfig, C: int):
    """Sort/gather-based static-capacity dispatch: identical routing
    semantics to the GShard einsum form but with O(tokens*d) data
    movement instead of O(tokens*E*C*d) dispatch-matmul FLOPs (a §Perf
    optimization; the einsum form is the paper-faithful baseline)."""
    G, S, E = gates.shape
    d = xg.shape[-1]
    K = m.top_k
    top_vals, top_idx = jax.lax.top_k(gates, K)               # [G,S,K]
    slot_expert = top_idx.reshape(G, S * K)                   # [G,N]
    slot_token = jnp.broadcast_to(
        jnp.arange(S)[:, None], (S, K)).reshape(S * K)
    slot_gate = top_vals.reshape(G, S * K).astype(jnp.float32)

    order = jnp.argsort(slot_expert, axis=1, stable=True)     # [G,N]
    sorted_e = jnp.take_along_axis(slot_expert, order, axis=1)
    sorted_t = slot_token[order]                              # [G,N]
    sorted_g = jnp.take_along_axis(slot_gate, order, axis=1)

    # position within the expert's run = index - start of the run
    counts = jnp.sum(jax.nn.one_hot(slot_expert, E, dtype=jnp.int32),
                     axis=1)                                   # [G,E]
    starts = jnp.cumsum(counts, axis=1) - counts               # [G,E]
    iota = jnp.broadcast_to(jnp.arange(S * K), (G, S * K))
    pos = iota - jnp.take_along_axis(starts, sorted_e, axis=1)
    keep = pos < C
    dest = jnp.where(keep, sorted_e * C + pos, E * C)          # drop slot

    xt = jnp.take_along_axis(
        xg, sorted_t[..., None].astype(jnp.int32), axis=1)     # [G,N,d]
    buf = jnp.zeros((G, E * C + 1, d), xg.dtype)
    buf = buf.at[jnp.arange(G)[:, None], dest].add(
        jnp.where(keep[..., None], xt, 0))
    xe = buf[:, :-1].reshape(G, E, C, d)
    return xe, (dest, sorted_t, sorted_g, keep)


def _gather_combine(ye, route, G, S, d):
    dest, sorted_t, sorted_g, keep = route
    E, C = ye.shape[1], ye.shape[2]
    flat = jnp.concatenate(
        [ye.reshape(G, E * C, d),
         jnp.zeros((G, 1, d), ye.dtype)], axis=1)
    out_slot = jnp.take_along_axis(
        flat, dest[..., None].astype(jnp.int32), axis=1)       # [G,N,d]
    w = (sorted_g * keep).astype(ye.dtype)[..., None]
    y = jnp.zeros((G, S, d), ye.dtype)
    y = y.at[jnp.arange(G)[:, None], sorted_t].add(out_slot * w)
    return y


def moe_ffn_ep(p: dict, x: jax.Array, m: MoEConfig, activation: str,
               x_sharding) -> jax.Array:
    """Explicit expert parallelism via shard_map — the MultiVic
    dataflow at mesh scale: expert weights stay STATIONARY in their
    2D shards (the paper's B blocks pinned in scratchpads) and the
    small thing — capacity-bounded token buffers — moves on a static
    all_to_all schedule.  The per-shard capacity is the compile-time
    worst-case assumption for dynamic routing (paper §3).

    x_sharding: the residual stream's NamedSharding (mesh + batch axes).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh = x_sharding.mesh
    batch_spec = (x_sharding.spec[0] if len(x_sharding.spec) else None)
    model_n = int(mesh.shape.get("model", 1))
    data_ax = "data" if "data" in mesh.axis_names else None
    B, S, d = x.shape
    E = m.num_experts
    assert E % model_n == 0, (E, model_n)
    # shard the token (seq) dim over "model" for dispatch if divisible
    seq_ax = "model" if (model_n > 1 and S % model_n == 0) else None
    model_ax = "model" if model_n > 1 else None
    has_up = "we_up" in p

    in_x = P(batch_spec, seq_ax, None)
    w_gd = P(model_ax, data_ax, None)
    w_df = P(model_ax, None, data_ax)

    data_n = int(mesh.shape.get("data", 1)) if data_ax else 1

    def local_fn(xl, router, *ws):
        wg, wd = (ws[0], ws[2]) if has_up else (ws[0], ws[1])
        wu = ws[1] if has_up else None
        bl, sl, _ = xl.shape
        N = bl * sl
        xf = xl.reshape(1, N, d)
        logits = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32),
                            router)
        gates = jax.nn.softmax(logits, axis=-1)
        C = m.capacity(N)
        xe, route = _gather_dispatch(xf, gates, m, C)
        buf = xe[0]                                     # [E, C, d]
        if model_ax and seq_ax:
            # tokens -> expert owners; experts stay put
            buf = jax.lax.all_to_all(buf, "model", split_axis=0,
                                     concat_axis=1, tiled=True)
            # [E_local, model_n*C, d]
        elif model_ax:
            # tokens replicated over "model" (e.g. decode): each shard
            # computes its local expert slice; results psum'd below.
            lo = jax.lax.axis_index("model") * (E // model_n)
            buf = jax.lax.dynamic_slice_in_dim(buf, lo, E // model_n, 0)
        # Gather this layer's d-slices of the LOCAL experts (the
        # double-buffered analogue of the paper's per-round B-block
        # DMA).  A psum-of-partials scheme that avoids this gather was
        # tried and refuted: it moves O(tokens_received * d_ff) bytes,
        # which exceeds the weight shard for both assigned MoE archs
        # (see EXPERIMENTS.md §Perf iteration log).
        if data_n > 1:
            wg = jax.lax.all_gather(wg, data_ax, axis=1, tiled=True)
            if wu is not None:
                wu = jax.lax.all_gather(wu, data_ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, data_ax, axis=2, tiled=True)
        hg = jnp.einsum("ecd,edf->ecf", buf, wg)
        hu = (jnp.einsum("ecd,edf->ecf", buf, wu)
              if wu is not None else None)
        h = activate(hg, hu, activation)
        ye = jnp.einsum("ecf,efd->ecd", h, wd)
        if model_ax and seq_ax:
            ye = jax.lax.all_to_all(ye, "model", split_axis=1,
                                    concat_axis=0, tiled=True)
        elif model_ax:
            lo = jax.lax.axis_index("model") * (E // model_n)
            full = jnp.zeros((E,) + ye.shape[1:], ye.dtype)
            ye = jax.lax.dynamic_update_slice_in_dim(full, ye, lo, 0)
        y = _gather_combine(ye[None], route, 1, N, d)
        y = y.reshape(bl, sl, d)
        if model_ax and not seq_ax:
            y = jax.lax.psum(y, "model")
        return y

    ws = (p["we_gate"], p["we_up"], p["we_down"]) if has_up \
        else (p["we_gate"], p["we_down"])
    wspecs = (w_gd, w_gd, w_df) if has_up else (w_gd, w_df)
    fn = shard_map(local_fn, mesh, (in_x, P(None, None)) + wspecs,
                   in_x)
    return fn(x, p["router"], *ws)


def moe_ffn(p: dict, x: jax.Array, m: MoEConfig, activation: str,
            impl: str = "einsum", x_sharding=None
            ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss).  Static shapes throughout.
    impl: "einsum" (GShard-faithful baseline) | "gather" (optimized)."""
    B, S, d = x.shape
    tokens = B * S
    gs = min(m.group_size, tokens)
    while tokens % gs:          # largest divisor <= group_size (static)
        gs -= 1
    G = tokens // gs
    C = m.capacity(gs)
    xg = x.reshape(G, gs, d)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)                   # fp32

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=1)                               # [G,E]
    top1 = jax.nn.one_hot(jnp.argmax(gates, -1), m.num_experts,
                          dtype=jnp.float32)
    ce = jnp.mean(top1, axis=1)                                # [G,E]
    aux = m.num_experts * jnp.mean(jnp.sum(me * ce, axis=-1))

    if impl == "ep" and x_sharding is not None:
        y = moe_ffn_ep(p, x, m, activation, x_sharding).reshape(G, gs, d)
    elif impl in ("gather", "ep"):      # "ep" without mesh -> gather
        xe, route = _gather_dispatch(xg, gates, m, C)
        hg = jnp.einsum("gecd,edf->gecf", xe, p["we_gate"])
        hu = (jnp.einsum("gecd,edf->gecf", xe, p["we_up"])
              if "we_up" in p else None)
        h = activate(hg, hu, activation)
        ye = jnp.einsum("gecf,efd->gecd", h, p["we_down"])
        y = _gather_combine(ye, route, G, gs, d)
    else:
        combine, dispatch = _topk_dispatch(gates, m.top_k, C)
        xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
        hg = jnp.einsum("gecd,edf->gecf", xe, p["we_gate"])
        hu = (jnp.einsum("gecd,edf->gecf", xe, p["we_up"])
              if "we_up" in p else None)
        h = activate(hg, hu, activation)
        ye = jnp.einsum("gecf,efd->gecd", h, p["we_down"])
        y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    if "shared" in p:
        y = y + dense_ffn(p["shared"], xg, activation)
    return y.reshape(B, S, d), aux
