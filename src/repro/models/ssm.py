"""Mamba2 (SSD — state-space duality) blocks, used by zamba2-7b.

The recurrence  h_t = exp(a_t) h_{t-1} + dt_t * B_t x_t^T,
                y_t = C_t · h_t + D * x_t
is computed in the chunked (matrix) form: intra-chunk attention-like
term + inter-chunk state carry via lax.scan.  Deterministic dataflow —
static-schedulable per the paper's requirement.  Exponents of the decay
segments are always <= 0 (scalar per-head decay), so the chunked form is
numerically stable without rescaling.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import rmsnorm
from repro.models.spec import Par


def ssm_dims(d_model: int, s: SSMConfig):
    d_inner = s.expand * d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_dim
    return d_inner, nheads, conv_dim


def mamba_spec(d_model: int, s: SSMConfig, dtype: str) -> dict:
    d_inner, nheads, conv_dim = ssm_dims(d_model, s)
    d_in_proj = 2 * d_inner + 2 * s.state_dim + nheads
    return {
        "in_proj": Par((d_model, d_in_proj), ("embed", "ffn"), init="scaled",
                       dtype=dtype),
        "conv_w": Par((s.conv_kernel, conv_dim), (None, "ffn"),
                      init="scaled", dtype=dtype),
        "conv_b": Par((conv_dim,), ("ffn",), init="zeros", dtype=dtype),
        "A_log": Par((nheads,), (None,), init="decay", dtype="float32"),
        "D": Par((nheads,), (None,), init="ones", dtype="float32"),
        "dt_bias": Par((nheads,), (None,), init="zeros", dtype="float32"),
        "norm": Par((d_inner,), (None,), init="ones", dtype="float32"),
        "out_proj": Par((d_inner, d_model), ("ffn", "embed"), init="scaled",
                        dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  x: [B,S,C]; w: [K,C]; state: [B,K-1,C]
    carries the last K-1 inputs for decode.  Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+K-1, C]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return y, new_state


def _split_proj(zxbcdt: jax.Array, d_inner: int, state: int, nheads: int):
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + d_inner + 2 * state]
    dt = zxbcdt[..., -nheads:]
    return z, xBC, dt


def ssd_chunked(x: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array,
                chunk: int, init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  [B,S,H,P]  (already multiplied by dt)
    a:  [B,S,H]    log-decay per step (<= 0)
    Bm: [B,S,N], Cm: [B,S,N]
    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    NC = S // chunk
    xc = x.reshape(Bsz, NC, chunk, H, P)
    ac = a.reshape(Bsz, NC, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, NC, chunk, N)
    Cc = Cm.reshape(Bsz, NC, chunk, N)

    ca = jnp.cumsum(ac, axis=2)                       # inclusive [B,NC,L,H]
    total = ca[:, :, -1]                              # [B,NC,H]

    # intra-chunk: y[t] += sum_{j<=t} (C_t.B_j) exp(ca_t - ca_j) x_j
    seg = ca[:, :, :, None, :] - ca[:, :, None, :, :]  # [B,NC,L(t),L(j),H]
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    seg = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bctn,bcjn->bctj", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    att = (cb[..., None] * seg).astype(x.dtype)        # [B,NC,L,L,H]
    y_intra = jnp.einsum("bctjh,bcjhp->bcthp", att, xc)

    # chunk boundary states: sum_j exp(total - ca_j) B_j x_j^T
    decay_end = jnp.exp(total[:, :, None, :] - ca)     # [B,NC,L,H]
    cstate = jnp.einsum("bclh,bcln,bclhp->bchnp",
                        decay_end.astype(x.dtype), Bc.astype(x.dtype), xc)

    s0 = (jnp.zeros((Bsz, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def boundary(carry, inp):
        cs, tot = inp                                  # [B,H,N,P], [B,H]
        new = carry * jnp.exp(tot)[:, :, None, None] + cs.astype(jnp.float32)
        return new, carry                              # emit state BEFORE

    total_t = jnp.moveaxis(total, 1, 0)                # [NC,B,H]
    cstate_t = jnp.moveaxis(cstate, 1, 0)              # [NC,B,H,N,P]
    final, prev_states = jax.lax.scan(boundary, s0, (cstate_t, total_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # [B,NC,H,N,P]

    # inter-chunk: y[t] += exp(ca_t) * C_t . S_prev
    y_inter = jnp.einsum("bctn,bcnhp->bcthp",
                         Cc.astype(x.dtype),
                         jnp.swapaxes(prev_states, 2, 3).astype(x.dtype))
    y_inter = y_inter * jnp.exp(ca)[..., None].astype(x.dtype)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, final.astype(x.dtype)


def mamba_forward(p: dict, x: jax.Array, s: SSMConfig,
                  state: Optional[dict] = None, return_state: bool = False):
    """Full-sequence Mamba2 block.  x: [B,S,d]."""
    d_model = x.shape[-1]
    d_inner, nheads, conv_dim = ssm_dims(d_model, s)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt = _split_proj(zxbcdt, d_inner, s.state_dim, nheads)
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xin = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + s.state_dim]
    Cm = xBC[..., d_inner + s.state_dim:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["A_log"]) * dt                                 # <= 0
    xh = xin.reshape(*xin.shape[:-1], nheads, s.head_dim)
    xdt = xh * dt[..., None].astype(xh.dtype)

    init_ssm = None if state is None else state["ssm"]
    S = x.shape[1]
    chunk = s.chunk_size if S % s.chunk_size == 0 else S
    y, final = ssd_chunked(xdt, a, Bm, Cm, chunk, init_ssm)
    y = y + xh * p["D"][:, None].astype(xh.dtype)
    y = y.reshape(*x.shape[:-1], d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        return out, {"conv": new_conv, "ssm": final}
    return out


def mamba_decode(p: dict, x: jax.Array, s: SSMConfig, state: dict):
    """Single-token decode.  x: [B,1,d]; state {conv [B,K-1,C],
    ssm [B,H,N,P]}."""
    d_model = x.shape[-1]
    d_inner, nheads, _ = ssm_dims(d_model, s)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt = _split_proj(zxbcdt, d_inner, s.state_dim, nheads)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                 state["conv"])
    xBC = jax.nn.silu(xBC)
    xin = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + s.state_dim]          # [B,1,N]
    Cm = xBC[..., d_inner + s.state_dim:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,1,H]
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                        # [B,1,H]
    xh = xin.reshape(x.shape[0], nheads, s.head_dim)              # [B,H,P]
    xdt = xh * dt[:, 0, :, None].astype(xh.dtype)

    S0 = state["ssm"].astype(jnp.float32)                         # [B,H,N,P]
    upd = jnp.einsum("bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                     xdt.astype(jnp.float32))
    S1 = S0 * a[:, 0, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), S1)
    y = y.astype(xh.dtype) + xh * p["D"][:, None].astype(xh.dtype)
    y = y.reshape(x.shape[0], 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": S1.astype(state["ssm"].dtype)}


def mamba_state_spec(batch: int, d_model: int, s: SSMConfig,
                     dtype: str) -> dict:
    d_inner, nheads, conv_dim = ssm_dims(d_model, s)
    return {
        "conv": Par((batch, s.conv_kernel - 1, conv_dim),
                    ("batch", None, "ffn"), init="zeros", dtype=dtype),
        "ssm": Par((batch, nheads, s.state_dim, s.head_dim),
                   ("batch", "heads", None, None), init="zeros",
                   dtype=dtype),
    }
