"""RWKV-6 ("Finch") blocks: data-dependent-decay linear attention
(WKV6) with token-shift mixing, plus the squared-ReLU channel mix.

WKV6 recurrence per head (K = key dim, V = value dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: [K, V])
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with per-channel decay w_t in (0,1) computed from the input (low-rank).

The chunked form factorizes the interval decay products
exp(e_t - cw_j); the k-side exponent (-cw_j >= 0) is clamped at
``_EXP_CLAMP`` to stay finite in fp32.  Contributions attenuated by more
than e^-30 are effectively zero, so the clamp is semantics-preserving at
fp32 resolution (validated against the exact sequential scan in
tests/test_kernel_wkv6.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RWKVConfig
from repro.models.spec import Par

_EXP_CLAMP = 30.0


def rwkv_dims(d_model: int, r: RWKVConfig):
    nheads = d_model // r.head_dim
    return nheads, r.head_dim


def timemix_spec(d_model: int, r: RWKVConfig, dtype: str) -> dict:
    nheads, hd = rwkv_dims(d_model, r)
    return {
        "maa_x": Par((d_model,), (None,), init="zeros", dtype="float32"),
        "maa_rkvwg": Par((5, d_model), (None, None), init="zeros",
                         dtype="float32"),
        "mix_w1": Par((d_model, 5 * r.mix_lora), ("embed", None),
                      init="scaled", dtype=dtype),
        "mix_w2": Par((5, r.mix_lora, d_model), (None, None, "embed"),
                      init="scaled", dtype=dtype),
        "w0": Par((d_model,), (None,), init="decay", dtype="float32"),
        "wd_w1": Par((d_model, r.decay_lora), ("embed", None),
                     init="scaled", dtype=dtype),
        "wd_w2": Par((r.decay_lora, d_model), (None, "embed"),
                     init="scaled", dtype=dtype),
        "wr": Par((d_model, d_model), ("embed", "heads"), init="scaled",
                  dtype=dtype),
        "wk": Par((d_model, d_model), ("embed", "heads"), init="scaled",
                  dtype=dtype),
        "wv": Par((d_model, d_model), ("embed", "heads"), init="scaled",
                  dtype=dtype),
        "wg": Par((d_model, d_model), ("embed", "heads"), init="scaled",
                  dtype=dtype),
        "u": Par((nheads, hd), (None, None), init="zeros", dtype="float32"),
        "ln_x": Par((d_model,), (None,), init="ones", dtype="float32"),
        "wo": Par((d_model, d_model), ("heads", "embed"), init="scaled",
                  dtype=dtype),
    }


def channelmix_spec(d_model: int, d_ff: int, dtype: str) -> dict:
    return {
        "maa_k": Par((d_model,), (None,), init="zeros", dtype="float32"),
        "maa_r": Par((d_model,), (None,), init="zeros", dtype="float32"),
        "wk": Par((d_model, d_ff), ("embed", "ffn"), init="scaled",
                  dtype=dtype),
        "wv": Par((d_ff, d_model), ("ffn", "embed"), init="scaled",
                  dtype=dtype),
        "wr": Par((d_model, d_model), ("embed", None), init="scaled",
                  dtype=dtype),
    }


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: x_{t-1}, with `prev` [B,1,d] carried across calls."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


# ---------------------------------------------------------------------------
# WKV6 kernels (reference forms; the Pallas kernel mirrors the chunked one)


def wkv6_sequential(r, k, v, w_log, u, init_state=None):
    """Exact per-step scan (oracle).  r,k,v,w_log: [B,S,H,K]; u: [H,K].
    Returns (y [B,S,H,V], final_state [B,H,K,V])."""
    B, S, H, K = r.shape
    s0 = (jnp.zeros((B, H, K, K), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(S_, inp):
        rt, kt, vt, wt = inp   # [B,H,K] each
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,K,V]
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       S_ + u[None, :, :, None] * kv)
        S_new = jnp.exp(wt)[..., None] * S_ + kv
        return S_new, y

    seq = lambda a: jnp.moveaxis(a, 1, 0).astype(jnp.float32)
    final, ys = jax.lax.scan(step, s0, (seq(r), seq(k), seq(v), seq(w_log)))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), final


def wkv6_chunked(r, k, v, w_log, u, chunk: int, init_state=None):
    """Chunked WKV6.  Shapes as in wkv6_sequential."""
    B, S, H, K = r.shape
    assert S % chunk == 0, (S, chunk)
    NC = S // chunk
    f32 = jnp.float32
    rc = r.reshape(B, NC, chunk, H, K).astype(f32)
    kc = k.reshape(B, NC, chunk, H, K).astype(f32)
    vc = v.reshape(B, NC, chunk, H, K).astype(f32)
    wc = w_log.reshape(B, NC, chunk, H, K).astype(f32)

    cw = jnp.cumsum(wc, axis=2)          # inclusive sums of log-decay
    e = cw - wc                          # exclusive
    total = cw[:, :, -1]                 # [B,NC,H,K]

    rq = rc * jnp.exp(e)                                    # exp <= 0
    kk = kc * jnp.exp(jnp.minimum(-cw, _EXP_CLAMP))         # clamped
    A = jnp.einsum("bclhk,bcmhk->bchlm", rq, kk)            # t=l, j=m
    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), k=-1)
    A = jnp.where(tril[None, None, None], A, 0.0)
    diag = jnp.einsum("bclhk,bclhk->bclh", rc * u[None, None], kc)
    y_intra = jnp.einsum("bchlm,bcmhk->bclhk", A, vc)
    y_intra = y_intra + diag[..., None] * vc

    # chunk state contributions: sum_j exp(total - cw_j) k_j ^T v_j
    kdec = kc * jnp.exp(total[:, :, None] - cw)             # exp <= 0
    cstate = jnp.einsum("bclhk,bclhv->bchkv", kdec, vc)

    s0 = (jnp.zeros((B, H, K, K), f32) if init_state is None
          else init_state.astype(f32))

    def boundary(carry, inp):
        cs, tot = inp
        new = carry * jnp.exp(tot)[..., None] + cs
        return new, carry

    final, prev = jax.lax.scan(
        boundary, s0, (jnp.moveaxis(cstate, 1, 0), jnp.moveaxis(total, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                          # [B,NC,H,K,V]

    y_inter = jnp.einsum("bclhk,bchkv->bclhv", rq, prev)
    y = (y_intra + y_inter).reshape(B, S, H, K)
    return y.astype(r.dtype), final


# ---------------------------------------------------------------------------
# layer-level forward


def _ddlerp(p, x, xprev):
    """RWKV6 data-dependent token-shift mixing -> (xr,xk,xv,xw,xg)."""
    dx = (xprev - x).astype(jnp.float32)
    xx = x.astype(jnp.float32) + dx * p["maa_x"]
    B, S, d = x.shape
    m = jnp.tanh(jnp.einsum("bsd,dl->bsl", xx.astype(x.dtype), p["mix_w1"]))
    m = m.reshape(B, S, 5, -1)
    adj = jnp.einsum("bsfl,fld->fbsd", m, p["mix_w2"]).astype(jnp.float32)
    outs = []
    for i in range(5):
        mi = p["maa_rkvwg"][i] + adj[i]
        outs.append((x.astype(jnp.float32) + dx * mi).astype(x.dtype))
    return outs  # r, k, v, w, g order


def timemix_forward(p: dict, x: jax.Array, r_cfg: RWKVConfig,
                    state: Optional[dict] = None,
                    return_state: bool = False, chunk: int = 0):
    """Full-sequence RWKV6 time-mix.  x: [B,S,d]."""
    nheads, hd = rwkv_dims(x.shape[-1], r_cfg)
    prev = None if state is None else state["shift"]
    xprev = _shift(x, prev)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xprev)

    B, S, d = x.shape
    rh = jnp.einsum("bsd,dk->bsk", xr, p["wr"]).reshape(B, S, nheads, hd)
    kh = jnp.einsum("bsd,dk->bsk", xk, p["wk"]).reshape(B, S, nheads, hd)
    vh = jnp.einsum("bsd,dk->bsk", xv, p["wv"]).reshape(B, S, nheads, hd)
    g = jnp.einsum("bsd,dk->bsk", xg, p["wg"])

    wl = jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["wd_w1"]))
    wl = jnp.einsum("bsl,ld->bsd", wl, p["wd_w2"]).astype(jnp.float32)
    w_log = -jnp.exp(p["w0"] + wl)                     # [B,S,d] <= 0
    w_log = w_log.reshape(B, S, nheads, hd)

    chunk = chunk or r_cfg.chunk_size
    init = None if state is None else state["wkv"]
    if S % chunk == 0 and S > 1:
        y, final = wkv6_chunked(rh, kh, vh, w_log, p["u"], chunk, init)
    else:
        y, final = wkv6_sequential(rh, kh, vh, w_log, p["u"], init)

    # per-head groupnorm (scale-only) then gate
    y32 = y.astype(jnp.float32)
    mu = jnp.mean(y32, axis=-1, keepdims=True)
    var = jnp.var(y32, axis=-1, keepdims=True)
    y32 = (y32 - mu) * jax.lax.rsqrt(var + 64e-5)
    y = (y32.reshape(B, S, d) * p["ln_x"]).astype(x.dtype)
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bsk,kd->bsd", y, p["wo"])
    if return_state:
        return out, {"shift": x[:, -1:],
                     "wkv": final.astype(x.dtype)}
    return out


def channelmix_forward(p: dict, x: jax.Array,
                       state: Optional[jax.Array] = None,
                       return_state: bool = False):
    prev = None if state is None else state
    xprev = _shift(x, prev)
    dx = (xprev - x).astype(jnp.float32)
    xk = (x.astype(jnp.float32) + dx * p["maa_k"]).astype(x.dtype)
    xr = (x.astype(jnp.float32) + dx * p["maa_r"]).astype(x.dtype)
    kh = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"])))
    kv = jnp.einsum("bsf,fd->bsd", kh, p["wv"])
    y = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"])) * kv
    if return_state:
        return y, x[:, -1:]
    return y


def rwkv_state_spec(batch: int, d_model: int, r: RWKVConfig,
                    dtype: str) -> dict:
    nheads, hd = rwkv_dims(d_model, r)
    return {
        "tm": {
            "shift": Par((batch, 1, d_model), ("batch", None, None),
                         init="zeros", dtype=dtype),
            "wkv": Par((batch, nheads, hd, hd),
                       ("batch", "heads", None, None), init="zeros",
                       dtype=dtype),
        },
        "cm": Par((batch, 1, d_model), ("batch", None, None), init="zeros",
                  dtype=dtype),
    }
