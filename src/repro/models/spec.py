"""Parameter-spec system: a single source of truth from which we derive
(a) randomly initialized parameter pytrees (smoke tests / examples),
(b) ShapeDtypeStructs with shardings (multi-pod dry-run, no allocation),
(c) PartitionSpec trees (pjit in/out shardings).

A leaf is a ``Par``: shape + logical axes + init style.  Builders in the
model modules compose nested dicts of Par; ``stack`` prepends the scan
("stack") dimension for repeated layers.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import ShardingRules


@dataclass(frozen=True)
class Par:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | scaled | decay
    scale: float = 0.02
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_par(x) -> bool:
    return isinstance(x, Par)


def stack(tree, n: int):
    """Prepend a scan/stack dimension of size n to every Par in tree."""
    return jax.tree.map(
        lambda p: replace(p, shape=(n,) + p.shape, axes=("stack",) + p.axes),
        tree, is_leaf=is_par)


def cast(tree, dtype: str):
    return jax.tree.map(lambda p: replace(p, dtype=dtype), tree,
                        is_leaf=is_par)


# ---------------------------------------------------------------------------
# realizations


def _init_leaf(p: Par, key) -> jax.Array:
    dt = jnp.dtype(p.dtype)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dt)
    if p.init == "ones":
        return jnp.ones(p.shape, dt)
    if p.init == "decay":
        # small negative values; used for SSM/RWKV decay parameters
        return jnp.asarray(
            -0.5 - 2.0 * jax.random.uniform(key, p.shape), dt)
    scale = p.scale
    if p.init == "scaled":
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        scale = 1.0 / np.sqrt(max(1, fan_in))
    return jnp.asarray(scale * jax.random.normal(key, p.shape, jnp.float32),
                       dt)


def init_tree(tree, key) -> dict:
    """Materialize random parameters for a spec tree."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_par)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(p, k) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def shape_tree(tree, rules: Optional[ShardingRules] = None) -> dict:
    """ShapeDtypeStructs (with shardings if rules given) — used by the
    dry-run so no memory is ever allocated for the full-size models."""
    def f(p: Par):
        if rules is None:
            return jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype))
        return jax.ShapeDtypeStruct(
            p.shape, jnp.dtype(p.dtype),
            sharding=rules.sharding_for(p.axes, p.shape))
    return jax.tree.map(f, tree, is_leaf=is_par)


def pspec_tree(tree, rules: ShardingRules):
    return jax.tree.map(lambda p: rules.spec_for(p.axes, p.shape), tree,
                        is_leaf=is_par)


def param_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_par)
    return int(sum(np.prod(p.shape, dtype=np.int64) *
                   jnp.dtype(p.dtype).itemsize for p in leaves))


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_par)
    return int(sum(np.prod(p.shape, dtype=np.int64) for p in leaves))
