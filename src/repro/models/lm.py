"""Unified language model: one entry point for all ten assigned
architectures (dense / sliding-window / MoE / hybrid-SSM / RWKV /
enc-dec / VLM-stub).

Public API
----------
  model_spec(cfg)                      -> Par tree (single source of truth)
  init_params(cfg, key)                -> random params (smoke/examples)
  cache_spec(cfg, batch, cache_len)    -> Par tree for decode state
  init_cache(cfg, batch, cache_len)    -> zero cache
  train_loss(cfg, params, batch, opts) -> scalar loss (fp32)
  prefill(cfg, params, batch, opts)    -> (last_logits [B,V], cache)
  decode_step(cfg, params, cache, token, pos, opts) -> (logits, cache)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import blocks as blk
from repro.models import ffn as ffn_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import rmsnorm, rmsnorm_spec
from repro.models.spec import Par, init_tree, stack

MAX_POS_TABLE = 32_768  # whisper learned-position tables


@dataclass(frozen=True, eq=False)
class RunOptions:
    chunk_q: int = 512
    chunk_kv: int = 512
    loss_chunk: int = 512
    cache_len: int = 0        # prefill: cache buffer length (0 = seq len)
    remat: bool = True
    aux_weight: float = 0.01  # MoE load-balance loss weight
    moe_impl: str = "einsum"  # einsum (GShard baseline) | gather (§Perf)
    windowed_cache: bool = False  # ring-buffer KV for sliding-window
    #                               layers (wincache variant, §Perf)
    # decode-loop structure: scan (one compiled unit body, small
    # program) vs unroll (per-unit programs fused end-to-end).  None =
    # follow cfg.scan_layers; the serving autotuner measures both and
    # pins the winner in the model plan ("decode_scan" 0/1).  Either
    # choice is numerically identical (tests/test_model_plan.py).
    decode_scan: Optional[bool] = None
    # activation sharding constraints (NamedShardings keyed by role);
    # None = single-device / let GSPMD infer.  Keys: "x" (residual
    # stream [B,S,d]), "logits" ([B,C,V]), "kv" (cache [B,S,KV,hd]).
    shardings: Optional[dict] = None


DEFAULT_OPTS = RunOptions()


def _wsc(x: jax.Array, opts: RunOptions, key: str) -> jax.Array:
    """Apply a with_sharding_constraint if configured.

    These constraints are the mesh-scale 'static schedule': they pin the
    activation layout the same way the paper's management core pins
    scratchpad residency, instead of letting the partitioner drift into
    replicated (interference-prone, memory-exploding) layouts."""
    if not opts.shardings:
        return x
    s = opts.shardings.get(key)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


# ---------------------------------------------------------------------------
# parameter / cache specs


def model_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    spec = {
        "embed": Par((cfg.padded_vocab, d), ("vocab", "embed"),
                     init="normal", dtype=cfg.dtype),
        "final_norm": rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = Par((cfg.padded_vocab, d), ("vocab", "embed"),
                              init="normal", dtype=cfg.dtype)
    for si, st in enumerate(blk.build_stages(cfg)):
        spec[f"stage{si}"] = blk.stage_spec(cfg, st)
    if cfg.family == "hybrid":
        spec["shared"] = stack(blk.shared_block_spec(cfg),
                               cfg.ssm.n_shared_blocks)
    if cfg.family == "encdec":
        enc = blk.encoder_stage(cfg)
        spec["encoder"] = {
            "stack": blk.stage_spec(cfg, enc),
            "norm": rmsnorm_spec(d),
            "pos": Par((MAX_POS_TABLE, d), (None, "embed"), init="normal",
                       dtype=cfg.dtype),
        }
        spec["dec_pos"] = Par((MAX_POS_TABLE, d), (None, "embed"),
                              init="normal", dtype=cfg.dtype)
    return spec


def init_params(cfg: ModelConfig, key) -> dict:
    return init_tree(model_spec(cfg), key)


def param_count(cfg: ModelConfig) -> int:
    """Total parameter count straight from the spec (no allocation) —
    what the serving WCET model sizes the per-step weight pass with."""
    import numpy as np

    from repro.models.spec import is_par
    return int(sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(model_spec(cfg),
                                            is_leaf=is_par)))


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int,
               windowed: bool = False) -> dict:
    spec = {}
    for si, st in enumerate(blk.build_stages(cfg)):
        spec[f"stage{si}"] = blk.stage_cache_spec(cfg, st, batch,
                                                  cache_len, windowed)
    return spec


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    return init_tree(cache_spec(cfg, batch, cache_len),
                     jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# embedding / logits


def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array,
           batch: Optional[dict] = None,
           opts: RunOptions = DEFAULT_OPTS) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    if (cfg.frontend.kind == "patches" and cfg.frontend.num_positions
            and batch is not None and "patch_embeds" in batch):
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    return _wsc(x, opts, "x")


def _head_table(cfg: ModelConfig, params: dict) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def compute_logits(cfg: ModelConfig, params: dict,
                   x: jax.Array) -> jax.Array:
    """x: [B, d] -> fp32 logits [B, padded_vocab] (padding masked)."""
    head = _head_table(cfg, params)
    logits = jnp.einsum("bd,vd->bv", x, head,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if cfg.padded_vocab != cfg.vocab_size:
        viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(viota < cfg.vocab_size, logits, -1e30)
    return logits


def lm_loss(cfg: ModelConfig, params: dict, x: jax.Array,
            targets: jax.Array, opts: RunOptions) -> jax.Array:
    """Chunked softmax cross-entropy (fp32 reductions).  x: [B,S,d]."""
    B, S, d = x.shape
    head = _head_table(cfg, params)
    C = opts.loss_chunk if (opts.loss_chunk and S % opts.loss_chunk == 0
                            and S > opts.loss_chunk) else S
    nch = S // C
    xc = jnp.moveaxis(x.reshape(B, nch, C, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nch, C), 1, 0)

    def body(tot, inp):
        xx, tt = inp
        logits = jnp.einsum("bcd,vd->bcv", xx, head,
                            preferred_element_type=jnp.float32)
        logits = _wsc(logits, opts, "logits")
        if cfg.padded_vocab != cfg.vocab_size:
            viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            logits = jnp.where(viota < cfg.vocab_size, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, tt[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc))
    return tot / (B * S)


# ---------------------------------------------------------------------------
# full-sequence unit application (train / prefill)


def _to_cache_buf(k: jax.Array, cache_len: int,
                  opts: RunOptions = DEFAULT_OPTS,
                  window: int = 0) -> jax.Array:
    if opts.windowed_cache and window > 0:
        L = min(cache_len, window)
        S = k.shape[1]
        if S > L:
            # ring layout: position p lives in slot p % L; the last L
            # positions cover every slot exactly once (cyclic shift)
            q0 = S - L
            kw = jax.lax.slice_in_dim(k, q0, S, axis=1)
            return _wsc(jnp.roll(kw, q0 % L, axis=1), opts, "kv")
        cache_len = L
    if cache_len <= k.shape[1]:
        return _wsc(k, opts, "kv")
    shape = (k.shape[0], cache_len) + k.shape[2:]
    buf = jax.lax.dynamic_update_slice(
        jnp.zeros(shape, k.dtype), k, (0, 0, 0, 0))
    return _wsc(buf, opts, "kv")


def _shared_block_full(cfg, sp, x, x0, positions, opts, collect):
    cat = jnp.concatenate([x, x0], axis=-1)
    h = rmsnorm(cat, sp["ln_in"])
    res = attn_mod.self_attention(
        sp["attn"], h, cfg.attention, positions,
        theta=cfg.attention.rope_theta, window=0, chunk_q=opts.chunk_q,
        chunk_kv=opts.chunk_kv, return_kv=collect)
    att, kv = res if collect else (res, None)
    x = x + att
    h2 = rmsnorm(x, sp["ln_ffn"])
    x = x + ffn_mod.dense_ffn(sp["ffn"], h2, cfg.activation)
    return x, kv


def _apply_unit_full(cfg: ModelConfig, up: dict, unit, x, x0, positions,
                     opts: RunOptions, collect: bool, memory, shared,
                     unit_idx, cache_len: int):
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    a = cfg.attention
    for i, dsc in enumerate(unit):
        p = up[f"pos{i}"]
        c = {}
        if dsc.kind in ("attn", "enc_attn"):
            h = rmsnorm(x, p["ln_attn"])
            res = attn_mod.self_attention(
                p["attn"], h, a, positions, theta=dsc.theta,
                window=dsc.window, chunk_q=opts.chunk_q,
                chunk_kv=opts.chunk_kv, causal=dsc.causal,
                return_kv=collect)
            att, kv = res if collect else (res, None)
            if cfg.use_post_norm:
                att = rmsnorm(att, p["ln_attn_post"])
            att = _wsc(att, opts, "x_sp")
            x = x + att
            h = rmsnorm(x, p["ln_ffn"])
            if dsc.use_moe:
                f, al = ffn_mod.moe_ffn(
                    p["moe"], h, cfg.moe, cfg.activation, opts.moe_impl,
                    opts.shardings.get("x") if opts.shardings else None)
                aux = aux + al
            else:
                f = ffn_mod.dense_ffn(p["ffn"], h, cfg.activation)
            if cfg.use_post_norm:
                f = rmsnorm(f, p["ln_ffn_post"])
            f = _wsc(f, opts, "x_sp")
            x = x + f
            if collect:
                c = {"k": _to_cache_buf(kv[0], cache_len, opts,
                                        dsc.window),
                     "v": _to_cache_buf(kv[1], cache_len, opts,
                                        dsc.window)}
        elif dsc.kind == "dec_attn":
            h = rmsnorm(x, p["ln_self"])
            res = attn_mod.self_attention(
                p["self"], h, a, positions, theta=0.0, window=0,
                chunk_q=opts.chunk_q, chunk_kv=opts.chunk_kv,
                return_kv=collect)
            att, kv = res if collect else (res, None)
            x = x + att
            h = rmsnorm(x, p["ln_cross"])
            ck, cv = attn_mod.cross_kv(p["cross"], memory, a)
            x = x + attn_mod.cross_attention(p["cross"], h, ck, cv, a)
            h = rmsnorm(x, p["ln_ffn"])
            x = x + ffn_mod.dense_ffn(p["ffn"], h, cfg.activation)
            if collect:
                c = {"k": _to_cache_buf(kv[0], cache_len, opts),
                     "v": _to_cache_buf(kv[1], cache_len, opts),
                     "ck": ck, "cv": cv}
        elif dsc.kind == "mamba":
            if dsc.shared_attn:
                sel = unit_idx % cfg.ssm.n_shared_blocks
                sp = blk.tree_index(shared, sel)
                x, skv = _shared_block_full(cfg, sp, x, x0, positions,
                                            opts, collect)
                if collect:
                    c["shared_k"] = _to_cache_buf(skv[0], cache_len, opts)
                    c["shared_v"] = _to_cache_buf(skv[1], cache_len, opts)
            h = rmsnorm(x, p["ln"])
            if collect:
                m, st = ssm_mod.mamba_forward(p["mamba"], h, cfg.ssm,
                                              None, return_state=True)
                c["conv"], c["ssm"] = st["conv"], st["ssm"]
            else:
                m = ssm_mod.mamba_forward(p["mamba"], h, cfg.ssm)
            x = x + m
        elif dsc.kind == "rwkv":
            h = rmsnorm(x, p["ln_tm"])
            if collect:
                tm, st = rwkv_mod.timemix_forward(
                    p["tm"], h, cfg.rwkv, None, return_state=True)
                c["tm"] = st
            else:
                tm = rwkv_mod.timemix_forward(p["tm"], h, cfg.rwkv)
            x = x + tm
            h = rmsnorm(x, p["ln_cm"])
            if collect:
                cm, st2 = rwkv_mod.channelmix_forward(p["cm"], h, None,
                                                      return_state=True)
                c["cm"] = st2
            else:
                cm = rwkv_mod.channelmix_forward(p["cm"], h)
            x = x + cm
        else:
            raise ValueError(dsc.kind)
        if collect:
            cache[f"pos{i}"] = c
    return x, aux, (cache if collect else None)


def _run_stage_full(cfg, sp, stage: blk.StageDescr, x, x0, positions, opts,
                    collect: bool, memory, shared, cache_len: int):
    idxs = jnp.arange(stage.n_units, dtype=jnp.int32)

    def body(carry, inp):
        xx, au = carry
        up, ui = inp
        xx, d_aux, cache = _apply_unit_full(
            cfg, up, stage.unit, xx, x0, positions, opts, collect, memory,
            shared, ui, cache_len)
        return (_wsc(xx, opts, "x"), au + d_aux), cache

    if opts.remat and not collect:
        body = jax.checkpoint(body)

    if cfg.scan_layers:
        (x, aux), caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (sp, idxs))
    else:
        aux = jnp.zeros((), jnp.float32)
        cl = []
        for i in range(stage.n_units):
            (x, aux), ci = body((x, aux),
                                (blk.tree_index(sp, i), jnp.int32(i)))
            cl.append(ci)
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *cl)
                  if collect else None)
    return x, aux, caches


def _encode(cfg: ModelConfig, params: dict, frames: jax.Array,
            opts: RunOptions) -> jax.Array:
    enc = params["encoder"]
    T = frames.shape[1]
    x = frames.astype(jnp.dtype(cfg.dtype)) + enc["pos"][:T]
    positions = jnp.arange(T, dtype=jnp.int32)
    st = blk.encoder_stage(cfg)
    x, _, _ = _run_stage_full(cfg, enc["stack"], st, x, x, positions, opts,
                              False, None, None, 0)
    return rmsnorm(x, enc["norm"])


def forward_hidden(cfg: ModelConfig, params: dict, batch: dict,
                   opts: RunOptions = DEFAULT_OPTS, collect: bool = False,
                   cache_len: int = 0):
    """Run embeddings + all stages.  Returns (x, aux, caches)."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens, batch, opts)
    if cfg.family == "encdec":
        S = tokens.shape[1]
        x = x + params["dec_pos"][:S]
        memory = _encode(cfg, params, batch["frames"], opts)
    else:
        memory = None
    x0 = x
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    shared = params.get("shared")
    aux = jnp.zeros((), jnp.float32)
    caches = {}
    for si, st in enumerate(blk.build_stages(cfg)):
        x, a_i, c_i = _run_stage_full(
            cfg, params[f"stage{si}"], st, x, x0, positions, opts, collect,
            memory, shared, cache_len)
        aux = aux + a_i
        caches[f"stage{si}"] = c_i
    x = rmsnorm(x, params["final_norm"])
    return x, aux, (caches if collect else None)


# ---------------------------------------------------------------------------
# training


def train_loss(cfg: ModelConfig, params: dict, batch: dict,
               opts: RunOptions = DEFAULT_OPTS) -> jax.Array:
    x, aux, _ = forward_hidden(cfg, params, batch, opts, collect=False)
    loss = lm_loss(cfg, params, x, batch["targets"], opts)
    return loss + opts.aux_weight * aux


# ---------------------------------------------------------------------------
# serving


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            opts: RunOptions = DEFAULT_OPTS):
    """Process the prompt; returns (last-token fp32 logits, cache)."""
    S = batch["tokens"].shape[1]
    cache_len = opts.cache_len or S
    x, _, caches = forward_hidden(cfg, params, batch, opts, collect=True,
                                  cache_len=cache_len)
    logits = compute_logits(cfg, params, x[:, -1])
    return logits, caches


def _apply_unit_decode(cfg: ModelConfig, up: dict, unit, x, x0, pos,
                       opts: RunOptions, cache_unit: dict, shared,
                       unit_idx):
    a = cfg.attention
    new_cache = {}
    for i, dsc in enumerate(unit):
        p = up[f"pos{i}"]
        c = cache_unit[f"pos{i}"]
        nc = {}
        if dsc.kind in ("attn", "enc_attn"):
            h = rmsnorm(x, p["ln_attn"])
            att, nk, nv = attn_mod.decode_attention(
                p["attn"], h, a, c["k"], c["v"], pos, theta=dsc.theta,
                window=dsc.window)
            if cfg.use_post_norm:
                att = rmsnorm(att, p["ln_attn_post"])
            x = x + att
            h = rmsnorm(x, p["ln_ffn"])
            if dsc.use_moe:
                f, _ = ffn_mod.moe_ffn(
                    p["moe"], h, cfg.moe, cfg.activation, opts.moe_impl,
                    opts.shardings.get("x") if opts.shardings else None)
            else:
                f = ffn_mod.dense_ffn(p["ffn"], h, cfg.activation)
            if cfg.use_post_norm:
                f = rmsnorm(f, p["ln_ffn_post"])
            x = x + f
            nc = {"k": nk, "v": nv}
        elif dsc.kind == "dec_attn":
            h = rmsnorm(x, p["ln_self"])
            att, nk, nv = attn_mod.decode_attention(
                p["self"], h, a, c["k"], c["v"], pos, theta=0.0, window=0)
            x = x + att
            h = rmsnorm(x, p["ln_cross"])
            x = x + attn_mod.cross_attention(p["cross"], h, c["ck"],
                                             c["cv"], a)
            h = rmsnorm(x, p["ln_ffn"])
            x = x + ffn_mod.dense_ffn(p["ffn"], h, cfg.activation)
            nc = {"k": nk, "v": nv, "ck": c["ck"], "cv": c["cv"]}
        elif dsc.kind == "mamba":
            if dsc.shared_attn:
                sel = unit_idx % cfg.ssm.n_shared_blocks
                sp = blk.tree_index(shared, sel)
                cat = jnp.concatenate([x, x0], axis=-1)
                h = rmsnorm(cat, sp["ln_in"])
                att, sk, sv = attn_mod.decode_attention(
                    sp["attn"], h, a, c["shared_k"], c["shared_v"], pos,
                    theta=a.rope_theta, window=0)
                x = x + att
                h2 = rmsnorm(x, sp["ln_ffn"])
                x = x + ffn_mod.dense_ffn(sp["ffn"], h2, cfg.activation)
                nc["shared_k"], nc["shared_v"] = sk, sv
            h = rmsnorm(x, p["ln"])
            m, st = ssm_mod.mamba_decode(p["mamba"], h, cfg.ssm,
                                         {"conv": c["conv"],
                                          "ssm": c["ssm"]})
            x = x + m
            nc["conv"], nc["ssm"] = st["conv"], st["ssm"]
        elif dsc.kind == "rwkv":
            h = rmsnorm(x, p["ln_tm"])
            tm, st = rwkv_mod.timemix_forward(p["tm"], h, cfg.rwkv,
                                              c["tm"], return_state=True)
            x = x + tm
            h = rmsnorm(x, p["ln_cm"])
            cm, st2 = rwkv_mod.channelmix_forward(p["cm"], h, c["cm"],
                                                  return_state=True)
            x = x + cm
            nc = {"tm": st, "cm": st2}
        else:
            raise ValueError(dsc.kind)
        new_cache[f"pos{i}"] = nc
    return x, new_cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos, opts: RunOptions = DEFAULT_OPTS):
    """One decode step.  token: [B] int32; pos: scalar position of the
    new token.  Returns (fp32 logits [B, padded_vocab], new cache)."""
    x = _embed(cfg, params, token[:, None], None, opts)
    if cfg.family == "encdec":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], jnp.asarray(pos, jnp.int32), 1, axis=0)
    x0 = x
    shared = params.get("shared")
    scan_units = (cfg.scan_layers if opts.decode_scan is None
                  else bool(opts.decode_scan))
    new_caches = {}
    for si, st in enumerate(blk.build_stages(cfg)):
        sp = params[f"stage{si}"]
        idxs = jnp.arange(st.n_units, dtype=jnp.int32)

        def body(xx, inp, _st=st):
            up, ui, cu = inp
            xx, nc = _apply_unit_decode(cfg, up, _st.unit, xx, x0, pos,
                                        opts, cu, shared, ui)
            return xx, nc

        if scan_units:
            x, nc = jax.lax.scan(body, x, (sp, idxs, cache[f"stage{si}"]))
        else:
            ncl = []
            for i in range(st.n_units):
                x, ci = body(x, (blk.tree_index(sp, i), jnp.int32(i),
                                 blk.tree_index(cache[f"stage{si}"], i)))
                ncl.append(ci)
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncl)
        new_caches[f"stage{si}"] = nc
    x = rmsnorm(x, params["final_norm"])
    logits = compute_logits(cfg, params, x[:, 0])
    return logits, new_caches
