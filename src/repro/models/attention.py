"""Attention layers: GQA self-attention (full / sliding-window / causal),
decode-with-cache, and cross-attention (enc-dec).

Implementation notes
--------------------
* One code path serves gemma3's 5:1 local:global pattern: the window size
  and rope theta enter as *traced per-layer metadata* (values, not
  shapes), so the layer stack scans over a single program — the MultiVic
  requirement of input-independent dataflow holds by construction.
* Training/prefill attention is computed in chunks with an online
  softmax (flash-attention dataflow) so the dry-run's memory analysis
  reflects a deployable program.  ``chunk_q/chunk_kv <= 0`` selects the
  single-block path (used by tests and by the roofline cost pieces,
  where it is FLOP-identical).
* All softmax arithmetic is fp32 regardless of model dtype.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.common import apply_rope, rmsnorm, rmsnorm_spec
from repro.models.spec import Par

NEG_INF = -1e30
_BIG_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# parameter specs


def attn_spec(d_model: int, a: AttentionConfig, dtype: str,
              d_out: Optional[int] = None) -> dict:
    hd, H, KV = a.head_dim, a.num_heads, a.num_kv_heads
    # "head_dim" resolves to the model axis only under the `kvshard`
    # rules variant AND only when the heads dim couldn't take it
    # (divisibility fallback) — see sharding/rules.py.
    p = {
        "wq": Par((d_model, H, hd), ("embed", "heads", "head_dim"),
                  init="scaled", dtype=dtype),
        "wk": Par((d_model, KV, hd), ("embed", "kv_heads", "head_dim"),
                  init="scaled", dtype=dtype),
        "wv": Par((d_model, KV, hd), ("embed", "kv_heads", "head_dim"),
                  init="scaled", dtype=dtype),
        "wo": Par((H, hd, d_out or d_model), ("heads", "head_dim",
                                              "embed"),
                  init="scaled", dtype=dtype),
    }
    if a.qkv_bias:
        p["bq"] = Par((H, hd), ("heads", None), init="zeros", dtype=dtype)
        p["bk"] = Par((KV, hd), ("kv_heads", None), init="zeros", dtype=dtype)
        p["bv"] = Par((KV, hd), ("kv_heads", None), init="zeros", dtype=dtype)
    if a.qk_norm:
        p["q_norm"] = rmsnorm_spec(hd)
        p["k_norm"] = rmsnorm_spec(hd)
    return p


# ---------------------------------------------------------------------------
# projections


def qkv_project(p: dict, x: jax.Array, a: AttentionConfig,
                positions: jax.Array, theta) -> Tuple[jax.Array, jax.Array,
                                                      jax.Array]:
    """x: [B, S, d] -> q [B,S,H,hd], k/v [B,S,KV,hd] (rope applied)."""
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if a.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if a.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if a.rope_theta > 0:  # static per-arch; whisper uses no rope
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def out_project(p: dict, o: jax.Array) -> jax.Array:
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# masked scaled-dot-product attention, chunked with online softmax


def _mask_bias(pos_q: jax.Array, pos_k: jax.Array, causal: bool,
               window) -> jax.Array:
    """[Sq, Tk] additive bias in fp32.  ``window`` may be traced."""
    dq = pos_q[:, None].astype(jnp.int32)
    dk = pos_k[None, :].astype(jnp.int32)
    ok = dk >= 0          # ring-buffer slots not yet written are < 0
    if causal:
        ok = ok & (dk <= dq)
    w_eff = jnp.where(jnp.asarray(window, jnp.int32) > 0,
                      jnp.asarray(window, jnp.int32), _BIG_WINDOW)
    ok = ok & (dq - dk < w_eff)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _block_attn(q: jax.Array, k: jax.Array, v: jax.Array, bias: jax.Array,
                scale: float) -> jax.Array:
    """Single-block reference attention.
    q: [B,Sq,KV,G,hd]; k,v: [B,Tk,KV,hd]; bias: [Sq,Tk]."""
    s = jnp.einsum("bqkgh,btkh->bkgqt", q, k).astype(jnp.float32) * scale
    s = s + bias[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(v.dtype), v)
    return o


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, pos_q: jax.Array,
         pos_k: jax.Array, *, causal: bool, window, scale: float,
         chunk_q: int = 0, chunk_kv: int = 0) -> jax.Array:
    """Grouped-query attention.  q: [B,Sq,H,hd] with H = KV*G;
    k,v: [B,Tk,KV,hd].  Returns [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)

    if chunk_q > 0 and Sq % chunk_q != 0:
        chunk_q = 0                       # graceful single-block fallback
    if chunk_kv > 0 and k.shape[1] % chunk_kv != 0:
        chunk_kv = 0

    if chunk_q <= 0 or chunk_q >= Sq:
        bias = _mask_bias(pos_q, pos_k, causal, window)
        o = _block_attn(qg, k, v, bias, scale)
        return o.reshape(B, Sq, H, hd)

    assert Sq % chunk_q == 0, (Sq, chunk_q)
    nq = Sq // chunk_q
    qc = jnp.moveaxis(qg.reshape(B, nq, chunk_q, KV, G, hd), 1, 0)
    pqc = pos_q.reshape(nq, chunk_q)

    Tk = k.shape[1]
    use_kv_chunks = chunk_kv > 0 and chunk_kv < Tk
    if use_kv_chunks:
        assert Tk % chunk_kv == 0, (Tk, chunk_kv)
        nk = Tk // chunk_kv
        kc = jnp.moveaxis(k.reshape(B, nk, chunk_kv, KV, hd), 1, 0)
        vc = jnp.moveaxis(v.reshape(B, nk, chunk_kv, KV, hd), 1, 0)
        pkc = pos_k.reshape(nk, chunk_kv)

    @jax.checkpoint
    def q_step(_, qi):
        # rematerialized in the backward pass (flash-attention-style):
        # per-q-chunk softmax stats are recomputed, never stored for the
        # whole sequence.
        qq, pq = qi
        if not use_kv_chunks:
            bias = _mask_bias(pq, pos_k, causal, window)
            return None, _block_attn(qq, k, v, bias, scale)

        # online softmax over kv chunks
        m0 = jnp.full((B, KV, G, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, chunk_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, chunk_q, hd), jnp.float32)

        def kv_step(carry, ki):
            m, l, acc = carry
            kk, vv, pk = ki
            s = jnp.einsum("bqkgh,btkh->bkgqt", qq, kk).astype(jnp.float32)
            s = s * scale + _mask_bias(pq, pk, causal, window)[None, None,
                                                              None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pexp.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", pexp, vv.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, pkc))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, jnp.einsum("bkgqh->bqkgh", o).astype(q.dtype)

    _, oc = jax.lax.scan(q_step, None, (qc, pqc))
    # oc: [nq, B, chunk_q, KV, G, hd] -> [B, Sq, H, hd]
    o = jnp.moveaxis(oc, 0, 1).reshape(B, Sq, KV, G, hd)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# layer-level entry points


def self_attention(p: dict, x: jax.Array, a: AttentionConfig,
                   positions: jax.Array, *, theta, window,
                   chunk_q: int = 512, chunk_kv: int = 512,
                   return_kv: bool = False, causal: bool = True):
    """Training / prefill self-attention over the whole sequence."""
    scale = a.softmax_scale or 1.0 / math.sqrt(a.head_dim)
    q, k, v = qkv_project(p, x, a, positions, theta)
    o = sdpa(q, k, v, positions, positions, causal=causal, window=window,
             scale=scale, chunk_q=chunk_q, chunk_kv=chunk_kv)
    y = out_project(p, o)
    if return_kv:
        return y, (k, v)
    return y


def decode_attention(p: dict, x: jax.Array, a: AttentionConfig,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos, *, theta, window):
    """Single-token decode.  x: [B, 1, d]; cache_k/v: [B, L, KV, hd];
    ``pos`` is the (traced) index of the new token.

    If the cache is SHORTER than the attention span could be (windowed
    ring buffer, L == window for a local layer), the write lands at
    pos % L and per-slot positions are reconstructed — slot s holds the
    newest position p <= pos with p % L == s.  Returns
    (y [B,1,d], new_cache_k, new_cache_v)."""
    scale = a.softmax_scale or 1.0 / math.sqrt(a.head_dim)
    positions = jnp.asarray(pos, jnp.int32)[None]
    q, k_new, v_new = qkv_project(p, x, a, positions, theta)
    zero = jnp.zeros((), jnp.int32)
    pos_i = jnp.asarray(pos, jnp.int32)
    L = cache_k.shape[1]
    is_ring = window > 0 and L <= window if isinstance(window, int) \
        else False
    slot = pos_i % L if is_ring else pos_i
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (zero, slot, zero, zero))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (zero, slot, zero, zero))
    s_idx = jnp.arange(L, dtype=jnp.int32)
    if is_ring:
        # newest position in each slot; slots "ahead" of pos wrap to
        # negative and are masked by the causal check in sdpa
        pos_k = pos_i - ((pos_i - s_idx) % L)
    else:
        pos_k = s_idx
    o = sdpa(q, cache_k, cache_v, positions, pos_k, causal=True,
             window=window, scale=scale, chunk_q=0, chunk_kv=0)
    return out_project(p, o), cache_k, cache_v


def cross_attention(p: dict, x: jax.Array, mem_k: jax.Array,
                    mem_v: jax.Array, a: AttentionConfig) -> jax.Array:
    """Enc-dec cross attention; memory K/V are precomputed from encoder
    output.  No mask (encoder memory fully visible)."""
    scale = a.softmax_scale or 1.0 / math.sqrt(a.head_dim)
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    if a.qkv_bias:
        q = q + p["bq"]
    pos_q = jnp.arange(x.shape[1], dtype=jnp.int32)
    pos_k = jnp.arange(mem_k.shape[1], dtype=jnp.int32)
    o = sdpa(q, mem_k, mem_v, pos_q, pos_k, causal=False, window=0,
             scale=scale, chunk_q=0, chunk_kv=0)
    return out_project(p, o)


def cross_kv(p: dict, memory: jax.Array, a: AttentionConfig):
    """Project encoder output once into cross-attention K/V."""
    k = jnp.einsum("bsd,dnh->bsnh", memory, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", memory, p["wv"])
    if a.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v
