"""Shared model pieces: norms, RoPE, activations, embedding helpers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.spec import Par


# ---------------------------------------------------------------------------
# norms

def rmsnorm_spec(dim: int) -> Par:
    return Par((dim,), (None,), init="ones", dtype="float32")


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# activations

def activate(h_gate: jax.Array, h_up: Optional[jax.Array],
             kind: str) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(h_gate) * h_up
    if kind == "geglu":
        return jax.nn.gelu(h_gate, approximate=True) * h_up
    if kind == "gelu":
        return jax.nn.gelu(h_gate, approximate=True)
    if kind == "relu_sq":
        return jnp.square(jax.nn.relu(h_gate))
    raise ValueError(f"unknown activation {kind}")


def is_gated(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# rotary embeddings

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)            # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: jax.Array | float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] int32.

    theta may be a traced scalar (per-layer metadata: gemma3 uses 10k for
    local layers and 1M for global layers with a single code path).
    """
    hd = x.shape[-1]
    exponent = jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
    freqs = 1.0 / (jnp.asarray(theta, jnp.float32) ** exponent)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [...,s,hd/2]
    angles = angles[..., None, :]                               # heads dim
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / logits

def embed_spec(vocab: int, d_model: int, dtype: str) -> Par:
    return Par((vocab, d_model), ("vocab", "embed"), init="normal",
               dtype=dtype)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    # one-hot-free gather; GSPMD turns this into a sharded gather over the
    # vocab-sharded table.
    return jnp.take(table, tokens, axis=0)


def logits_from_embed(table: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, table)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits
