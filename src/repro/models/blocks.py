"""Layer-stack assembly.

A model is a sequence of *stages*; each stage scans over ``n_units``
repeat units; a unit is a fixed tuple of layer descriptors (positions)
unrolled inside the scan body.  This gives one traced program per stage
regardless of depth (compile-time friendly at 512 devices) while
supporting heterogeneous patterns:

  gemma3    : 1 stage, 8 units  x [L,L,L,L,L,G] attention layers
  llama4    : 1 stage, 24 units x [moe_layer, dense_layer]
  zamba2    : stage0: 13 units x [shared_attn+mamba, mamba x5],
              stage1: 1 unit   x [mamba x3]     (81 = 13*6 + 3)
  others    : 1 stage, n_layers units x [layer]

Static per-position metadata (window size, rope theta, moe flag) is
baked into the traced program; per-unit dynamic metadata (the unit
index, for zamba2's alternating tied blocks) is scanned over.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import rmsnorm, rmsnorm_spec
from repro.models.spec import Par, stack


# ---------------------------------------------------------------------------
# descriptors


@dataclass(frozen=True)
class LayerDescr:
    kind: str                  # attn | mamba | rwkv | enc_attn | dec_attn
    window: int = 0            # 0 = global
    theta: float = 10_000.0
    use_moe: bool = False
    shared_attn: bool = False  # zamba2: tied attn block applied first
    causal: bool = True


@dataclass(frozen=True)
class StageDescr:
    n_units: int
    unit: Tuple[LayerDescr, ...]

    @property
    def unit_len(self) -> int:
        return len(self.unit)


def build_stages(cfg: ModelConfig) -> Tuple[StageDescr, ...]:
    a = cfg.attention
    if cfg.family in ("dense", "vlm"):
        if a.layer_pattern:
            unit = tuple(
                LayerDescr("attn",
                           window=a.window_for_layer(i),
                           theta=(a.rope_theta_global or a.rope_theta)
                           if a.window_for_layer(i) == 0 else a.rope_theta)
                for i in range(len(a.layer_pattern)))
            return (StageDescr(cfg.num_layers // len(unit), unit),)
        unit = (LayerDescr("attn", theta=a.rope_theta),)
        return (StageDescr(cfg.num_layers, unit),)

    if cfg.family == "moe":
        m = cfg.moe
        if m.moe_every == 1:
            unit = (LayerDescr("attn", theta=a.rope_theta, use_moe=True),)
            return (StageDescr(cfg.num_layers, unit),)
        unit = tuple(
            LayerDescr("attn", theta=a.rope_theta,
                       use_moe=(i % m.moe_every == 0))
            for i in range(m.moe_every))
        return (StageDescr(cfg.num_layers // m.moe_every, unit),)

    if cfg.family == "hybrid":
        s = cfg.ssm
        per = s.shared_attn_every
        n_full = cfg.num_layers // per
        tail = cfg.num_layers - n_full * per
        unit = tuple(
            LayerDescr("mamba", shared_attn=(i == 0)) for i in range(per))
        stages = [StageDescr(n_full, unit)]
        if tail:
            stages.append(StageDescr(
                1, tuple(LayerDescr("mamba") for _ in range(tail))))
        return tuple(stages)

    if cfg.family == "rwkv":
        return (StageDescr(cfg.num_layers, (LayerDescr("rwkv"),)),)

    if cfg.family == "encdec":
        unit = (LayerDescr("dec_attn", theta=0.0),)
        return (StageDescr(cfg.num_layers, unit),)

    raise ValueError(cfg.family)


def encoder_stage(cfg: ModelConfig) -> StageDescr:
    assert cfg.family == "encdec"
    return StageDescr(cfg.encdec.encoder_layers,
                      (LayerDescr("enc_attn", theta=0.0, causal=False),))


# ---------------------------------------------------------------------------
# per-layer parameter specs


def layer_spec(cfg: ModelConfig, dsc: LayerDescr) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    if dsc.kind in ("attn", "enc_attn"):
        p = {
            "ln_attn": rmsnorm_spec(d),
            "attn": attn_mod.attn_spec(d, cfg.attention, dt),
            "ln_ffn": rmsnorm_spec(d),
        }
        if dsc.use_moe:
            p["moe"] = ffn_mod.moe_spec(d, cfg.moe, cfg.activation, dt)
        else:
            p["ffn"] = ffn_mod.dense_ffn_spec(d, cfg.d_ff, cfg.activation,
                                              dt)
        if cfg.use_post_norm:
            p["ln_attn_post"] = rmsnorm_spec(d)
            p["ln_ffn_post"] = rmsnorm_spec(d)
        return p
    if dsc.kind == "dec_attn":
        return {
            "ln_self": rmsnorm_spec(d),
            "self": attn_mod.attn_spec(d, cfg.attention, dt),
            "ln_cross": rmsnorm_spec(d),
            "cross": attn_mod.attn_spec(d, cfg.attention, dt),
            "ln_ffn": rmsnorm_spec(d),
            "ffn": ffn_mod.dense_ffn_spec(d, cfg.d_ff, cfg.activation, dt),
        }
    if dsc.kind == "mamba":
        return {
            "ln": rmsnorm_spec(d),
            "mamba": ssm_mod.mamba_spec(d, cfg.ssm, dt),
        }
    if dsc.kind == "rwkv":
        return {
            "ln_tm": rmsnorm_spec(d),
            "tm": rwkv_mod.timemix_spec(d, cfg.rwkv, dt),
            "ln_cm": rmsnorm_spec(d),
            "cm": rwkv_mod.channelmix_spec(d, cfg.d_ff, dt),
        }
    raise ValueError(dsc.kind)


def shared_block_spec(cfg: ModelConfig) -> dict:
    """zamba2's weight-tied attention block operating on concat(x, x0)."""
    d, dt = cfg.d_model, cfg.dtype
    return {
        "ln_in": rmsnorm_spec(2 * d),
        "attn": attn_mod.attn_spec(2 * d, cfg.attention, dt, d_out=d),
        "ln_ffn": rmsnorm_spec(d),
        "ffn": ffn_mod.dense_ffn_spec(d, cfg.d_ff, cfg.activation, dt),
    }


def stage_spec(cfg: ModelConfig, stage: StageDescr) -> dict:
    unit = {f"pos{i}": layer_spec(cfg, dsc)
            for i, dsc in enumerate(stage.unit)}
    return stack(unit, stage.n_units)


# ---------------------------------------------------------------------------
# cache specs (decode state)


def layer_cache_spec(cfg: ModelConfig, dsc: LayerDescr, batch: int,
                     cache_len: int, windowed: bool = False) -> dict:
    dt = cfg.dtype
    a = cfg.attention
    if dsc.kind in ("attn", "enc_attn"):
        L = cache_len
        if windowed and dsc.window > 0:
            # ring buffer: a sliding-window layer never attends past
            # `window` tokens back, so its cache is O(window), not
            # O(seq) — the big long-context memory lever for
            # local:global archs like gemma3 (see §Perf).
            L = min(cache_len, dsc.window)
        return {
            "k": Par((batch, L, a.num_kv_heads, a.head_dim),
                     ("batch", "kv_seq", "kv_heads", None), init="zeros",
                     dtype=dt),
            "v": Par((batch, L, a.num_kv_heads, a.head_dim),
                     ("batch", "kv_seq", "kv_heads", None), init="zeros",
                     dtype=dt),
        }
    if dsc.kind == "dec_attn":
        ek = cfg.encdec.cross_kv_len
        return {
            "k": Par((batch, cache_len, a.num_kv_heads, a.head_dim),
                     ("batch", "kv_seq", "kv_heads", None), init="zeros",
                     dtype=dt),
            "v": Par((batch, cache_len, a.num_kv_heads, a.head_dim),
                     ("batch", "kv_seq", "kv_heads", None), init="zeros",
                     dtype=dt),
            "ck": Par((batch, ek, a.num_kv_heads, a.head_dim),
                      ("batch", None, "kv_heads", None), init="zeros",
                      dtype=dt),
            "cv": Par((batch, ek, a.num_kv_heads, a.head_dim),
                      ("batch", None, "kv_heads", None), init="zeros",
                      dtype=dt),
        }
    if dsc.kind == "mamba":
        c = ssm_mod.mamba_state_spec(batch, cfg.d_model, cfg.ssm, dt)
        if dsc.shared_attn:
            c["shared_k"] = Par(
                (batch, cache_len, a.num_kv_heads, a.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=dt)
            c["shared_v"] = Par(
                (batch, cache_len, a.num_kv_heads, a.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=dt)
        return c
    if dsc.kind == "rwkv":
        return rwkv_mod.rwkv_state_spec(batch, cfg.d_model, cfg.rwkv, dt)
    raise ValueError(dsc.kind)


def stage_cache_spec(cfg: ModelConfig, stage: StageDescr, batch: int,
                     cache_len: int, windowed: bool = False) -> dict:
    unit = {f"pos{i}": layer_cache_spec(cfg, dsc, batch, cache_len,
                                        windowed)
            for i, dsc in enumerate(stage.unit)}
    return stack(unit, stage.n_units)


# ---------------------------------------------------------------------------
# tree helpers


def tree_index(tree, i):
    """Static or traced index into the leading (stack) axis."""
    if isinstance(i, int):
        return jax.tree.map(lambda a: a[i], tree)
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        tree)
