from repro.optim.adamw import (adamw_init, adamw_init_spec, adamw_update,
                               cosine_lr, global_norm, make_train_step)

__all__ = ["adamw_init", "adamw_init_spec", "adamw_update", "cosine_lr",
           "global_norm", "make_train_step"]
