"""AdamW + LR schedules + gradient clipping + the jit-able train step.

Pure JAX (no optax dependency).  Moments are fp32 regardless of the
(bf16) parameter dtype; the update math runs in fp32 and is cast back.
Optimizer state is sharded exactly like the parameters (ZeRO-style: the
fsdp/tensor shards of a weight own the matching shard of its moments).
"""
from __future__ import annotations

import dataclasses
from dataclasses import replace
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import lm as lm_mod
from repro.models.spec import Par, is_par


# ---------------------------------------------------------------------------
# schedules


def cosine_lr(tcfg: TrainConfig) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = tcfg.learning_rate * (step + 1) / max(1, tcfg.warmup_steps)
        prog = jnp.clip((step - tcfg.warmup_steps)
                        / max(1, tcfg.total_steps - tcfg.warmup_steps),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog)) * tcfg.learning_rate
        return jnp.where(step < tcfg.warmup_steps, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_init_spec(spec_tree) -> dict:
    """Par-tree for the optimizer state (for dry-run ShapeDtypeStructs)."""
    f32 = lambda p: replace(p, dtype="float32", init="zeros")
    return {
        "m": jax.tree.map(f32, spec_tree, is_leaf=is_par),
        "v": jax.tree.map(f32, spec_tree, is_leaf=is_par),
        "count": Par((), (), init="zeros", dtype="int32"),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, opt_state, params, tcfg: TrainConfig,
                 lr_fn: Callable):
    count = opt_state["count"] + 1
    lr = lr_fn(opt_state["count"])
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9)) \
        if tcfg.grad_clip > 0 else 1.0

    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        step = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            step = step + wd * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new, v_new

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    # out is a tree of 3-tuples at the leaves of params
    p_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": m_new, "v": v_new, "count": count}
    return p_new, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# train step factory


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    opts: Optional[lm_mod.RunOptions] = None):
    """Returns step(params, opt_state, batch, loss_scale=1.0) ->
    (params, opt_state, metrics).  Microbatching (gradient
    accumulation) happens via lax.scan when tcfg.microbatch > 1.

    Non-finite guard: if the (scaled) loss or the gradient norm comes
    out NaN/Inf — a transient numeric fault, real or injected via
    ``loss_scale`` — the update is discarded inside the jitted step
    (params/opt_state pass through unchanged, bit-exact) and
    ``metrics["finite"]`` is 0; the trainer retries the step.  On the
    healthy path the select keeps the freshly computed leaves, so
    finite steps are bit-identical to the unguarded step."""
    opts = opts or lm_mod.DEFAULT_OPTS
    lr_fn = cosine_lr(tcfg)
    base_loss_fn = lambda p, b: lm_mod.train_loss(cfg, p, b, opts)

    def step(params, opt_state, batch, loss_scale=1.0):
        # scale *inside* the differentiated function so a NaN scale
        # poisons gradients too (the realistic fault shape); scale 1.0
        # is an IEEE no-op, keeping healthy steps bit-exact
        loss_fn = lambda p, b: base_loss_fn(p, b) * loss_scale
        if tcfg.microbatch and tcfg.microbatch > 1:
            nm = tcfg.microbatch

            def split(x):
                return jnp.moveaxis(
                    x.reshape((nm, x.shape[0] // nm) + x.shape[1:]), 0, 0)

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                tot_loss, tot_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (tot_loss + l,
                        jax.tree.map(jnp.add, tot_grads, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zero_g), micro)
            loss = loss / nm
            grads = jax.tree.map(lambda g: g / nm, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        p_new, s_new, info = adamw_update(grads, opt_state, params,
                                          tcfg, lr_fn)
        finite = jnp.isfinite(loss) & jnp.isfinite(info["grad_norm"])
        keep = lambda new, old: jnp.where(finite, new, old)
        p_out = jax.tree.map(keep, p_new, params)
        s_out = jax.tree.map(keep, s_new, opt_state)
        metrics = {"loss": loss, "finite": finite, **info}
        return p_out, s_out, metrics

    return step
