"""int8 gradient compression for the cross-pod data-parallel axis.

At multi-pod scale the "pod" axis rides the slowest links (DCN), and
the gradient all-reduce across pods is pure data parallelism — the
classic place for lossy compression.  Scheme (per leaf):

    scale  = psum_max(|g|) / 127          (exact, tiny)
    q      = round(g / scale)  : int8
    g_hat  = psum(q.int32) * scale / n_pods

4x fewer bytes than fp32 (2x vs bf16) on the pod axis; within-pod
FSDP/TP reduction stays exact.  Wrapped with shard_map over ONLY the
pod axis (`auto` leaves data/model to GSPMD), so it composes with the
existing train step unchanged.

Error bound: |g_hat - mean(g)| <= scale/2 per element (uniform
quantization), property-tested in tests/test_compression.py.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


def _compress_psum_leaf(g: jax.Array, axis: str) -> jax.Array:
    gf = g.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return (total.astype(jnp.float32) * scale / n).astype(g.dtype)


def compressed_grad_mean(grads: Any, mesh, axis: str = "pod") -> Any:
    """Mean of per-pod gradients with int8 wire format.

    grads: pytree of per-pod partial gradients (already reduced within
    the pod).  Uses shard_map over the pod axis only; other mesh axes
    stay under GSPMD (auto)."""
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return grads
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    auto = frozenset(a for a in mesh.axis_names if a != axis)

    def fn(g):
        return jax.tree.map(partial(_compress_psum_leaf, axis=axis), g)

    spec = jax.tree.map(lambda _: P(), grads)
    return shard_map(fn, mesh, (spec,), spec, auto=auto)(grads)


def quantize_roundtrip(g: jax.Array) -> jax.Array:
    """Single-host reference of the wire format (for tests/error
    analysis): quantize to int8 with the global max-scale, dequantize."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)
