"""repro: MultiVic-on-TPU — a statically-scheduled, interference-free
multi-worker JAX training/inference framework reproducing

  "MultiVic: A Time-Predictable RISC-V Multi-Core Processor Optimized
   for Neural Network Inference" (Kirschner et al., 2025)

See DESIGN.md for the paper -> TPU mapping.
"""

__version__ = "1.0.0"
