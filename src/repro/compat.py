"""Single compatibility seam for every version-sensitive JAX surface.

The repo targets JAX 0.4.37 through current.  Upstream has renamed or
moved several APIs we depend on; the paper's predictability story
(PAPER.md §III: one statically-known substrate, identical behaviour
everywhere) forbids scattering per-version branches through kernels and
launch code.  All drift is absorbed here:

  * Pallas TPU compiler params: ``TPUCompilerParams`` (<= 0.4.x) was
    renamed ``CompilerParams`` (>= 0.5) -> ``tpu_compiler_params()``.
  * ``jax.sharding.AxisType`` / ``jax.make_mesh(axis_types=...)``
    (>= 0.5 only) -> ``AxisType`` fallback enum + ``make_mesh()``.
  * ``Compiled.cost_analysis()`` returns a list of per-computation
    dicts on 0.4.x and a flat dict on >= 0.5 ->
    ``cost_analysis()`` / ``normalize_cost_analysis()``.
  * ``shard_map`` lives at ``jax.shard_map`` (new) or
    ``jax.experimental.shard_map`` (old) and renamed its replication
    check ``check_rep`` -> ``check_vma`` -> ``shard_map()``.
  * Pallas interpret-mode selection off-TPU -> ``resolve_interpret()``.

Policy (enforced by scripts/check_compat_imports.py, run as a tier-1
test): no module outside this file may reference the raw symbols
directly.
"""
from __future__ import annotations

import enum
import functools
import inspect
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax

__all__ = [
    "JAX_VERSION",
    "jax_version_at_least",
    "tpu_compiler_params",
    "AxisType",
    "auto_axis_types",
    "make_mesh",
    "cost_analysis",
    "normalize_cost_analysis",
    "on_tpu",
    "resolve_interpret",
    "shard_map",
    "donated_jit",
    "aot_compile",
]


def _parse_version(v: str) -> Tuple[int, ...]:
    parts = []
    for p in v.split(".")[:3]:
        digits = "".join(ch for ch in p if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


JAX_VERSION: Tuple[int, ...] = _parse_version(jax.__version__)


def jax_version_at_least(*version: int) -> bool:
    return JAX_VERSION >= tuple(version)


# --------------------------------------------------- Pallas TPU params

def _pltpu():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu


def _resolve_tpu_compiler_params_cls(mod=None):
    """New layout first (>= 0.5), then the 0.4.x name.  ``mod`` is
    injectable for unit tests."""
    mod = mod if mod is not None else _pltpu()
    for name in ("Compiler" "Params", "TPUCompiler" "Params"):
        cls = getattr(mod, name, None)
        if cls is not None:
            return cls
    raise AttributeError(
        "jax.experimental.pallas.tpu exposes no compiler-params class "
        f"(jax {jax.__version__}); update repro.compat")


def tpu_compiler_params(*, dimension_semantics: Optional[Sequence[str]]
                        = None, **kwargs) -> Any:
    """Construct Pallas TPU compiler params under any supported JAX.

    Unknown fields are dropped (not an error): a field the installed
    JAX doesn't know is a hint it cannot honour, never a hard failure.
    """
    cls = _resolve_tpu_compiler_params_cls()
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    try:
        accepted = set(inspect.signature(cls).parameters)
    except (TypeError, ValueError):  # pragma: no cover
        accepted = set(kwargs)
    return cls(**{k: v for k, v in kwargs.items() if k in accepted})


# ------------------------------------------------------ mesh / AxisType

class _FallbackAxisType(enum.Enum):
    """Stand-in for the >= 0.5 axis-type enum on older JAX.  The values
    only matter as distinct markers; pre-0.5 meshes are implicitly all
    ``Auto`` so ``make_mesh`` simply drops them."""
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "Axis" "Type", _FallbackAxisType)


def auto_axis_types(n: int) -> Tuple[Any, ...]:
    """``(AxisType.Auto,) * n`` under whichever enum is in force."""
    return (AxisType.Auto,) * n


@functools.lru_cache(maxsize=1)
def _make_mesh_params() -> frozenset:
    return frozenset(inspect.signature(jax.make_mesh).parameters)


def _mesh_kwargs(supported: frozenset, axis_types, devices) -> Dict:
    kw: Dict[str, Any] = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and "axis_types" in supported:
        kw["axis_types"] = axis_types
    return kw


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates pre-0.5 signatures: on JAX
    without ``axis_types`` the request is dropped (old meshes behave as
    all-Auto, which is exactly what dropping yields)."""
    kw = _mesh_kwargs(_make_mesh_params(), axis_types, devices)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


# -------------------------------------------------------- cost analysis

def normalize_cost_analysis(raw) -> Dict[str, float]:
    """Flatten ``Compiled.cost_analysis()`` output to one str->float
    dict regardless of JAX version.

    0.4.x returns ``[{...}]`` (one record per computation; the first is
    the main program), >= 0.5 returns the dict itself, and some
    backends return ``None``.
    """
    if raw is None:
        return {}
    if isinstance(raw, (list, tuple)):
        raw = raw[0] if raw else {}
    if not isinstance(raw, Mapping):  # pragma: no cover - defensive
        return {}
    out = {}
    for k, v in raw.items():
        try:
            out[str(k)] = float(v)
        except (TypeError, ValueError):
            continue
    return out


def cost_analysis(compiled) -> Dict[str, float]:
    """Normalized cost analysis of a compiled executable."""
    return normalize_cost_analysis(compiled.cost_analysis())


# ------------------------------------------------- donation / AOT jit

def donated_jit(fn, *, donate_argnums: Tuple[int, ...] = (),
                static_argnums: Tuple[int, ...] = ()):
    """``jax.jit`` with buffer donation, degrading gracefully where the
    backend cannot honour it.

    Donation is the serving steady state's realloc killer (the KV cache
    is updated in place instead of copied every decode step), but CPU —
    the validation backend — implements it only partially and warns on
    every compile.  Requesting donation only where it works keeps the
    timed region identical across backends without drowning CPU runs in
    warnings; the *semantics* (caller must not reuse donated args) are
    the same either way, so code tested on CPU is donation-correct on
    TPU.
    """
    if jax.default_backend() not in ("tpu", "gpu"):
        donate_argnums = ()
    return jax.jit(fn, donate_argnums=donate_argnums,
                   static_argnums=static_argnums)


def aot_compile(jitted, *args, **kwargs):
    """Ahead-of-time compile a jitted callable for example arguments.

    ``jit(...).lower(...).compile()`` is the stable AOT spelling across
    the supported span (jax.stages); wrapping it here keeps launch code
    off the raw surface and gives one place to absorb future drift.
    The returned executable runs with ZERO compile-time jitter — the
    serving loop compiles before its timed region starts.
    """
    return jitted.lower(*args, **kwargs).compile()


# ---------------------------------------------------- interpret select

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Kernel entry points take ``interpret=None`` = auto: compile on
    TPU, interpret everywhere else (CPU validation path)."""
    if interpret is None:
        return not on_tpu()
    return bool(interpret)


# ----------------------------------------------------------- shard_map

def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn
    return fn


def _shard_map_kwargs(params: frozenset, *, check: bool,
                      auto: frozenset, axis_names: Sequence[str]) -> Dict:
    """Map our stable options onto whichever spelling the resolved
    shard_map uses (pure; unit-tested against both layouts)."""
    kw: Dict[str, Any] = {}
    if "check_vma" in params:
        kw["check_vma"] = check
    elif "check_rep" in params:
        kw["check_rep"] = check
    if auto:
        if "auto" in params:
            kw["auto"] = auto
        elif "axis_names" in params:
            kw["axis_names"] = set(axis_names) - set(auto)
    return kw


def shard_map(f, mesh, in_specs, out_specs, *, check: bool = False,
              auto: frozenset = frozenset()):
    """Version-stable shard_map.

    ``check`` maps to ``check_vma`` (new) / ``check_rep`` (old);
    ``auto`` is the set of mesh axes left to GSPMD, translated to the
    new API's complementary ``axis_names`` when needed.
    """
    fn = _resolve_shard_map()
    params = frozenset(inspect.signature(fn).parameters)
    kw = _shard_map_kwargs(params, check=check, auto=auto,
                           axis_names=mesh.axis_names)
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
