"""Span/counter trace recorder.

One recorder instance collects timing events from heterogeneous
sources: the discrete-event simulator stamps spans with *explicit*
cycle timestamps (one track per serial resource — the DMA engine and
each worker core), while runtime code (trainer step loop, kernel
conformance harness) uses wall-clock spans via the ``span()`` context
manager.  A recorder therefore carries a ``time_unit`` label so the
exporter and readers know what the numbers mean; mixing clock domains
in one recorder is the caller's mistake, not something we try to
auto-convert.

Spans on the same track must nest properly (begin/end are a stack per
track) — the invariant chrome://tracing assumes for duration events and
the one our tests enforce.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Span:
    """A closed interval of work on one track."""

    name: str
    track: str                 # resource / thread label (serial lane)
    start: float
    end: float
    cat: str = ""
    args: Tuple[Tuple[str, Any], ...] = ()

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclass(frozen=True)
class Counter:
    """A sampled scalar time series (chrome 'C' event)."""

    name: str
    t: float
    value: float
    track: str = "counters"


@dataclass(frozen=True)
class Instant:
    """A zero-duration marker (chrome 'i' event)."""

    name: str
    t: float
    track: str = "main"
    args: Tuple[Tuple[str, Any], ...] = ()


class TraceRecorder:
    """Append-only event sink; cheap enough to thread everywhere."""

    def __init__(self, time_unit: str = "us"):
        self.time_unit = time_unit
        self.spans: List[Span] = []
        self.counters: List[Counter] = []
        self.instants: List[Instant] = []
        self._open: Dict[str, List[Tuple[str, float, str,
                                         Tuple[Tuple[str, Any], ...]]]] = {}

    # ------------------------------------------------------------ clock

    @staticmethod
    def now() -> float:
        """Wall clock in microseconds (chrome ts convention)."""
        return time.perf_counter() * 1e6

    # ----------------------------------------------- explicit-time spans

    def add_span(self, name: str, track: str, start: float, end: float,
                 cat: str = "", **args: Any) -> Span:
        """Record an already-closed span (simulator path: caller owns
        the clock and stamps cycle times)."""
        assert end >= start, (name, start, end)
        sp = Span(name, track, float(start), float(end), cat,
                  tuple(sorted(args.items())))
        self.spans.append(sp)
        return sp

    # -------------------------------------------------- begin/end stack

    def begin(self, name: str, track: str = "main",
              t: Optional[float] = None, cat: str = "",
              **args: Any) -> None:
        t = self.now() if t is None else float(t)
        self._open.setdefault(track, []).append(
            (name, t, cat, tuple(sorted(args.items()))))

    def end(self, track: str = "main",
            t: Optional[float] = None) -> Span:
        """Close the innermost open span on ``track``."""
        stack = self._open.get(track)
        if not stack:
            raise ValueError(f"end() with no open span on {track!r}")
        t = self.now() if t is None else float(t)
        name, start, cat, args = stack.pop()
        sp = Span(name, track, start, max(start, t), cat, args)
        self.spans.append(sp)
        return sp

    def span(self, name: str, track: str = "main", cat: str = "",
             **args: Any) -> "_SpanCtx":
        """``with rec.span("step"): ...`` — wall-clock convenience."""
        return _SpanCtx(self, name, track, cat, args)

    @property
    def open_spans(self) -> int:
        return sum(len(s) for s in self._open.values())

    # --------------------------------------------------- scalar streams

    def counter(self, name: str, value: float,
                t: Optional[float] = None,
                track: str = "counters") -> None:
        self.counters.append(Counter(
            name, self.now() if t is None else float(t), float(value),
            track))

    def instant(self, name: str, track: str = "main",
                t: Optional[float] = None, **args: Any) -> None:
        self.instants.append(Instant(
            name, self.now() if t is None else float(t), track,
            tuple(sorted(args.items()))))

    # ------------------------------------------------------- inspection

    def tracks(self) -> List[str]:
        names = {s.track for s in self.spans}
        names.update(i.track for i in self.instants)
        return sorted(names)

    def spans_on(self, track: str) -> List[Span]:
        return [s for s in self.spans if s.track == track]

    def busy(self) -> Dict[str, float]:
        """Summed span duration per track (simulator spans: exactly the
        per-resource busy cycles)."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.track] = out.get(s.track, 0.0) + s.dur
        return out

    def __len__(self) -> int:
        return len(self.spans) + len(self.counters) + len(self.instants)


class _SpanCtx:
    def __init__(self, rec: TraceRecorder, name: str, track: str,
                 cat: str, args: Dict[str, Any]):
        self._rec, self._name, self._track = rec, name, track
        self._cat, self._args = cat, args

    def __enter__(self) -> "_SpanCtx":
        self._rec.begin(self._name, self._track, cat=self._cat,
                        **self._args)
        return self

    def __exit__(self, *exc) -> None:
        self._rec.end(self._track)
