"""Execution-time fluctuation metrics (paper §5.1, Fig. 4).

The paper evaluates predictability by running each benchmark many times
and reporting how little the cycle count moves.  ``jitter_stats``
condenses a sample vector into the fluctuation metrics we track across
PRs, and ``simulate_sweep`` produces that vector from seeded simulator
runs together with the WCET bound so every report carries its margin:

    wcet_margin = wcet(schedule) / max(observed)   (>= 1 iff bound holds)
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from repro.configs.multivic_paper import MultiVicConfig
from repro.core.schedule import Schedule
from repro.core.simulator import sweep_cycles
from repro.core.timing import DEFAULT_TIMING, TimingParams
from repro.core.wcet import wcet


@dataclass(frozen=True)
class JitterStats:
    """Fluctuation summary of one timing sample vector."""

    n: int
    mean: float
    median: float
    std: float
    min: float
    max: float
    spread: float           # max - min: the observed jitter window
    p99: float
    cov: float              # coefficient of variation: std / mean
    wcet_margin: Optional[float] = None   # wcet / max (None: no bound)

    def as_dict(self) -> Dict[str, Optional[float]]:
        return asdict(self)


def jitter_stats(samples: Sequence[float],
                 wcet_bound: Optional[float] = None) -> JitterStats:
    x = np.asarray(list(samples), dtype=np.float64)
    if x.size == 0:
        raise ValueError("jitter_stats needs at least one sample")
    mean = float(x.mean())
    mx = float(x.max())
    return JitterStats(
        n=int(x.size),
        mean=mean,
        median=float(np.median(x)),
        std=float(x.std()),
        min=float(x.min()),
        max=mx,
        spread=float(mx - x.min()),
        p99=float(np.percentile(x, 99)),
        cov=float(x.std() / mean) if mean else 0.0,
        wcet_margin=(float(wcet_bound) / mx
                     if wcet_bound is not None and mx else None),
    )


def simulate_sweep(sched: Schedule, hw: MultiVicConfig,
                   n_runs: int = 100,
                   tp: TimingParams = DEFAULT_TIMING,
                   seed0: int = 0,
                   include_wcet: bool = True) -> JitterStats:
    """The paper's measurement protocol as a metric source: ``n_runs``
    seeded executions (seeds ``seed0 .. seed0+n_runs-1``, matching
    ``run_many``) summarized with the WCET margin attached."""
    cycles = sweep_cycles(sched, hw, n_runs=n_runs, tp=tp, seed0=seed0)
    bound = wcet(sched, hw, tp) if include_wcet else None
    return jitter_stats(cycles, wcet_bound=bound)
