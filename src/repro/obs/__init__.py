"""Predictability observatory.

The paper's headline claim is *low execution-time fluctuation*, not raw
speed (§5.1).  ``repro.core`` can simulate jitter and bound it; this
package makes it observable:

- ``trace``        — :class:`TraceRecorder`: lightweight span/counter
  recorder shared by the cycle-accurate simulator (explicit cycle
  timestamps) and the wall-clock paths (trainer step loop, kernel
  conformance harness).
- ``chrome_trace`` — export a recorder to the Chrome trace-event JSON
  format (load in ``chrome://tracing`` / Perfetto).
- ``jitter``       — the paper's fluctuation metrics (mean, p99,
  max−min spread, coefficient of variation, WCET margin) over seeded
  simulator sweeps.
- ``report``       — schema-versioned structured sink for
  ``benchmarks/run.py --json`` so the BENCH trajectory is machine-
  readable instead of print-only CSV.
"""
from repro.obs.chrome_trace import to_chrome_trace, write_chrome_trace
from repro.obs.jitter import JitterStats, jitter_stats, simulate_sweep
from repro.obs.report import (BENCH_SCHEMA_VERSION, hw_fingerprint,
                              make_report, validate_report)
from repro.obs.trace import Counter, Instant, Span, TraceRecorder

__all__ = [
    "BENCH_SCHEMA_VERSION",
    "Counter",
    "Instant",
    "JitterStats",
    "Span",
    "TraceRecorder",
    "hw_fingerprint",
    "jitter_stats",
    "make_report",
    "simulate_sweep",
    "to_chrome_trace",
    "validate_report",
    "write_chrome_trace",
]
