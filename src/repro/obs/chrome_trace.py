"""Chrome trace-event exporter.

Serializes a :class:`~repro.obs.trace.TraceRecorder` to the JSON object
format consumed by ``chrome://tracing`` and Perfetto: one complete
('X') event per span with ``ts``/``dur``, 'C' events for counters, 'i'
for instants, plus 'M' metadata events naming each track.  Tracks map
to tids inside a single pid so the resource lanes (dma, core0..N)
render as parallel swimlanes — the schedule Gantt chart the paper draws
by hand.

``ts`` is nominally microseconds; the simulator records cycle
timestamps, which view fine (1 cycle renders as 1 us) — the recorder's
``time_unit`` is carried in ``otherData`` so readers can re-scale.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.obs.trace import TraceRecorder

PID = 1


def _tid_map(rec: TraceRecorder) -> Dict[str, int]:
    tracks = rec.tracks()
    tracks.extend(sorted({c.track for c in rec.counters
                          if c.track not in tracks}))
    return {t: i + 1 for i, t in enumerate(tracks)}


def to_chrome_trace(rec: TraceRecorder) -> Dict[str, Any]:
    """Return the trace as a JSON-serializable dict."""
    tids = _tid_map(rec)
    events: List[Dict[str, Any]] = []
    for track, tid in tids.items():
        events.append({"ph": "M", "pid": PID, "tid": tid,
                       "name": "thread_name",
                       "args": {"name": track}})
    for s in rec.spans:
        events.append({"ph": "X", "pid": PID, "tid": tids[s.track],
                       "name": s.name, "cat": s.cat or "span",
                       "ts": s.start, "dur": s.dur,
                       "args": dict(s.args)})
    for c in rec.counters:
        events.append({"ph": "C", "pid": PID, "tid": tids[c.track],
                       "name": c.name, "ts": c.t,
                       "args": {c.name: c.value}})
    for i in rec.instants:
        events.append({"ph": "i", "pid": PID, "tid": tids[i.track],
                       "name": i.name, "ts": i.t, "s": "t",
                       "args": dict(i.args)})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"time_unit": rec.time_unit,
                      "producer": "repro.obs"},
    }


def write_chrome_trace(rec: TraceRecorder, path: str) -> str:
    """Dump the trace to ``path``; returns the path for chaining."""
    with open(path, "w", encoding="utf-8") as f:
        json.dump(to_chrome_trace(rec), f, indent=None,
                  separators=(",", ":"))
    return path
