"""Structured benchmark report (the ``--json`` sink of benchmarks/run.py).

Replaces grep-the-CSV archaeology with a schema-versioned document so
the BENCH trajectory is machine-readable: per-benchmark ``us_per_call``
and the same ``derived`` payload the CSV carries, plus optional
``jitter`` blocks (the Fig. 4 fluctuation metrics) and a hardware/
software fingerprint so numbers from different environments are never
compared blindly.

``validate_report`` is a hand-rolled structural check (no jsonschema
dependency); it returns a list of error strings — empty means valid —
and is what tests and future tooling call before trusting a report.
"""
from __future__ import annotations

import hashlib
import platform
import sys
import time
from typing import Any, Dict, List, Optional, Sequence

BENCH_SCHEMA_VERSION = 1

# jitter blocks mirror obs.jitter.JitterStats.as_dict()
_JITTER_KEYS = ("n", "mean", "median", "std", "min", "max", "spread",
                "p99", "cov", "wcet_margin")


def hw_fingerprint() -> Dict[str, Any]:
    """Environment identity attached to every report."""
    jax_ver = backend = None
    try:                                   # bench subset without jax
        import jax
        jax_ver = jax.__version__
        # device identity — the tuning plan cache keys on this too
        backend = jax.default_backend()
    except Exception:
        pass
    import numpy as np

    from repro.configs.multivic_paper import PAPER_CONFIGS
    cfg_digest = hashlib.sha256(
        "|".join(repr(c) for c in PAPER_CONFIGS).encode()).hexdigest()
    return {
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "machine": platform.machine(),
        "jax": jax_ver,
        "backend": backend,
        "numpy": np.__version__,
        "paper_configs_sha256": cfg_digest,
    }


def make_report(rows: Sequence[Dict[str, Any]], *,
                fast: bool = False,
                generated_at: Optional[float] = None) -> Dict[str, Any]:
    """Build the schema-v1 document from benchmark rows.

    Rows are the same dicts the CSV printer consumes
    (``name`` / ``us_per_call`` / ``derived``); an optional ``jitter``
    key (a ``JitterStats.as_dict()``) rides along untouched.
    """
    benchmarks = []
    for r in rows:
        entry: Dict[str, Any] = {
            "name": str(r["name"]),
            "us_per_call": float(r["us_per_call"]),
            "derived": str(r["derived"]),
        }
        if r.get("jitter") is not None:
            entry["jitter"] = dict(r["jitter"])
        benchmarks.append(entry)
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "generated_by": "benchmarks.run",
        "generated_at": float(time.time() if generated_at is None
                              else generated_at),
        "fast": bool(fast),
        "hw_fingerprint": hw_fingerprint(),
        "benchmarks": benchmarks,
    }


def validate_report(doc: Any) -> List[str]:
    """Structural validation; returns error strings (empty = valid)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"report must be an object, got {type(doc).__name__}"]
    if doc.get("schema_version") != BENCH_SCHEMA_VERSION:
        errs.append(f"schema_version must be {BENCH_SCHEMA_VERSION}, "
                    f"got {doc.get('schema_version')!r}")
    for key, typ in (("generated_by", str), ("generated_at", float),
                     ("fast", bool), ("hw_fingerprint", dict),
                     ("benchmarks", list)):
        if not isinstance(doc.get(key), typ):
            errs.append(f"missing or mistyped field {key!r} "
                        f"(want {typ.__name__})")
    fp = doc.get("hw_fingerprint")
    if isinstance(fp, dict):
        for key in ("python", "platform", "numpy",
                    "paper_configs_sha256"):
            if key not in fp:
                errs.append(f"hw_fingerprint missing {key!r}")
    for i, b in enumerate(doc.get("benchmarks") or []):
        where = f"benchmarks[{i}]"
        if not isinstance(b, dict):
            errs.append(f"{where} must be an object")
            continue
        if not isinstance(b.get("name"), str) or not b.get("name"):
            errs.append(f"{where}.name must be a non-empty string")
        if not isinstance(b.get("us_per_call"), (int, float)):
            errs.append(f"{where}.us_per_call must be a number")
        if not isinstance(b.get("derived"), str):
            errs.append(f"{where}.derived must be a string")
        if "jitter" in b:
            j = b["jitter"]
            if not isinstance(j, dict):
                errs.append(f"{where}.jitter must be an object")
                continue
            for key in _JITTER_KEYS:
                if key not in j:
                    errs.append(f"{where}.jitter missing {key!r}")
                elif key != "wcet_margin" and not isinstance(
                        j[key], (int, float)):
                    errs.append(f"{where}.jitter.{key} must be a number")
    return errs
