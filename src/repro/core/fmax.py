"""Critical-path / routing-congestion model for F_max (paper Tables 1-2).

We cannot run Vivado synthesis here, so F_max is modeled with two
physically-motivated basis terms fitted (least squares) to the seven
published measurements:

  f = A + B*L + C*L^2 - D*max(0, ports - P0)^2
      L = log2(multiplier width)        (vector-unit critical path)
      ports = 2*W + 2                   (SPM ports on the DMA crossbar:
                                         I+D per worker + mgmt, §5.1)

The quadratic congestion term reproduces the paper's observation that
scalability breaks at 16 cores because of FPGA routing congestion from
34 scratchpad connections.  Residuals are asserted < 5% in tests.
"""
from __future__ import annotations

import numpy as np

from repro.configs.multivic_paper import PAPER_CONFIGS, MultiVicConfig

P0_PORTS = 8.0


def _features(hw: MultiVicConfig) -> np.ndarray:
    L = np.log2(hw.vicuna.mul_width_bits)
    ports = 2 * hw.num_worker_cores + 2
    cong = max(0.0, ports - P0_PORTS) ** 2
    return np.array([1.0, L, L * L, -cong])


def fit_fmax_model() -> np.ndarray:
    X = np.stack([_features(c) for c in PAPER_CONFIGS])
    y = np.array([c.fmax_hz / 1e6 for c in PAPER_CONFIGS])
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    return coef


_COEF = None


def predict_fmax_mhz(hw: MultiVicConfig) -> float:
    global _COEF
    if _COEF is None:
        _COEF = fit_fmax_model()
    return float(_features(hw) @ _COEF)


def model_table():
    """(name, measured MHz, modeled MHz, rel err) for every config."""
    rows = []
    for c in PAPER_CONFIGS:
        pred = predict_fmax_mhz(c)
        meas = c.fmax_hz / 1e6
        rows.append((c.name, meas, pred, (pred - meas) / meas))
    return rows
