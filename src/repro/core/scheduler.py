"""Compile-time scheduler: the paper's matmul mapping (§4.3) plus a
general blocked-GEMM scheduler for arbitrary (M, K, N).

Mapping (paper-faithful):
  * B (K x N) is partitioned into column blocks of width ``bw`` chosen
    so a block FITS in a worker's data scratchpad next to the
    double-buffered A-row and C-fragment buffers; each round, core w
    receives one block which stays resident for the whole round
    ("as long as possible", §4.3).
  * Within a round, rows of A are streamed (double-buffered DMA) into
    every core; each core computes the bw-wide fragments of C rows and
    the DMA writes fragments back.  Multiple rounds cover all N columns
    (A is re-streamed per round — the cost of finite SPM).
  * Inside a core, each output element is a dot product over K computed
    as ceil(K / VL) vector-MAC chunks (output-vectorized inner loop) +
    a reduction/store epilogue.

The resulting Schedule is input-data-independent — exactly the static
schedule the management core executes.  SPM capacity feasibility is
part of schedule construction, not an afterthought.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

from repro.configs.multivic_paper import ELEM_BYTES, MATMUL_N, MultiVicConfig
from repro.core.schedule import DMA, Schedule, core_resource


@dataclass(frozen=True)
class MatmulProblem:
    m: int = MATMUL_N
    k: int = MATMUL_N
    n: int = MATMUL_N

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


def vl_elems(hw: MultiVicConfig) -> int:
    return hw.vicuna.vreg_bits // (8 * ELEM_BYTES)


def spm_plan(hw: MultiVicConfig, prob: MatmulProblem,
             rows_per_transfer: int = 4) -> dict:
    """Choose the widest B-block (multiple of VL) that fits in the SPM
    beside 2 A-row buffers and 2 C-fragment buffers."""
    vl = vl_elems(hw)
    a_buf = 2 * rows_per_transfer * prob.k * ELEM_BYTES
    avail = hw.data_spm_bytes - a_buf
    bw_max = avail // (prob.k * ELEM_BYTES + 2 * rows_per_transfer
                       * ELEM_BYTES)
    bw = max(vl, (bw_max // vl) * vl)
    b_block = prob.k * bw * ELEM_BYTES
    fits = (b_block + a_buf + 2 * rows_per_transfer * bw * ELEM_BYTES
            <= hw.data_spm_bytes)
    cols_per_round = bw * hw.num_worker_cores
    n_rounds = math.ceil(prob.n / cols_per_round)
    return {"bw": bw, "vl": vl, "b_block_bytes": b_block, "fits": fits,
            "n_rounds": n_rounds, "cols_per_round": cols_per_round,
            "rows_per_transfer": rows_per_transfer,
            "spm_bytes": hw.data_spm_bytes}


def _col_blocks(hw: MultiVicConfig, prob: MatmulProblem, bw: int
                ) -> List[List[int]]:
    """Per round, the block width each core owns (0 = idle)."""
    W = hw.num_worker_cores
    rounds = []
    remaining = prob.n
    while remaining > 0:
        widths = []
        for _ in range(W):
            w = min(bw, remaining)
            widths.append(w)
            remaining -= w
            if remaining <= 0:
                widths.extend([0] * (W - len(widths)))
                break
        rounds.append(widths)
    return rounds


def build_matmul_schedule(hw: MultiVicConfig,
                          prob: MatmulProblem = MatmulProblem(),
                          rows_per_transfer: int = 4) -> Schedule:
    W = hw.num_worker_cores
    plan = spm_plan(hw, prob, rows_per_transfer)
    assert plan["fits"], plan
    bw, vl = plan["bw"], plan["vl"]
    chunks_per_elem = math.ceil(prob.k / vl)
    R = rows_per_transfer
    assert prob.m % R == 0
    n_iters = prob.m // R

    sched = Schedule(meta={"hw": hw.name, "problem": vars(prob), **plan})
    rounds = _col_blocks(hw, prob, bw)

    last_compute = {w: None for w in range(W)}
    for widths in rounds:
        # 1) B blocks for this round (DMA serialized; B buffer reuse
        #    requires the core's previous-round compute to be done)
        load_b = {}
        for w, width in enumerate(widths):
            if width == 0:
                continue
            deps = (last_compute[w],) if last_compute[w] is not None else ()
            load_b[w] = sched.add(
                kind="dma_load", resource=DMA,
                bytes_moved=prob.k * width * ELEM_BYTES,
                deps=deps, spm_core=w, tag=f"B->c{w}")

        # 2) stream A row-groups; compute; write back C fragments.
        # DMA issue order matters (the management core executes the
        # phase list in order, and the DMA is serial): all loads for
        # iteration it+1 are issued BEFORE the stores of iteration it,
        # so a store waiting on a long compute never starves the loads
        # the other cores' next computes depend on.
        active = [w for w, width in enumerate(widths) if width > 0]
        comp_hist = {w: [] for w in active}    # per-core compute phases

        def add_loads(it):
            loads = {}
            for w in active:
                deps = [load_b[w]]
                if len(comp_hist[w]) >= 2:      # A double buffer depth 2
                    deps.append(comp_hist[w][-2])
                loads[w] = sched.add(
                    kind="dma_load", resource=DMA,
                    bytes_moved=R * prob.k * ELEM_BYTES,
                    deps=tuple(deps), spm_core=w, tag=f"A{it}->c{w}")
            return loads

        pending_loads = add_loads(0)
        for it in range(n_iters):
            cur_loads = pending_loads
            comps = {}
            for w in active:
                width = widths[w]
                comp_deps = [cur_loads[w]]
                if comp_hist[w]:
                    comp_deps.append(comp_hist[w][-1])
                comps[w] = sched.add(
                    kind="compute", resource=core_resource(w),
                    deps=tuple(comp_deps),
                    macs=R * prob.k * width,
                    vec_chunks=R * width * chunks_per_elem,
                    elems=R * width,
                    spm_core=w, tag=f"C{it},{w}")
                comp_hist[w].append(comps[w])
            if it + 1 < n_iters:
                pending_loads = add_loads(it + 1)
            for w in active:
                sched.add(
                    kind="dma_store", resource=DMA,
                    bytes_moved=R * widths[w] * ELEM_BYTES,
                    deps=(comps[w],), spm_core=w, tag=f"C{it},{w}->ddr")
        last_compute.update({w: comp_hist[w][-1] for w in active})

    sched.validate_dag()
    sched.validate_interference_freedom()
    return sched


def schedule_totals(sched: Schedule) -> dict:
    macs = sum(p.macs for p in sched.phases)
    dma_bytes = sum(p.bytes_moved for p in sched.phases)
    return {"macs": macs, "dma_bytes": dma_bytes,
            "n_phases": len(sched.phases),
            "n_dma": sum(1 for p in sched.phases if p.kind != "compute")}
