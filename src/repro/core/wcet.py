"""Compositional WCET analysis.

Because the architecture is interference-free and timing-anomaly-free
(paper §3.1, citing Hahn/Reineke/Wilhelm compositionality), a global
WCET can be composed from per-phase worst-case bounds: evaluate the
schedule DAG with every phase at its local worst case.  The invariant

        simulate(schedule, any jitter draw)  <=  wcet(schedule)

is exercised as a hypothesis property test (tests/).
"""
from __future__ import annotations

from repro.configs.multivic_paper import MultiVicConfig
from repro.core.schedule import Schedule
from repro.core.simulator import simulate
from repro.core.timing import DEFAULT_TIMING, TimingParams, phase_wcet


def wcet(sched: Schedule, hw: MultiVicConfig,
         tp: TimingParams = DEFAULT_TIMING) -> float:
    """Exact bound: list-schedule with worst-case durations."""
    return simulate(sched, hw, tp, worst_case=True).total_cycles


def wcet_closed_form(sched: Schedule, hw: MultiVicConfig,
                     tp: TimingParams = DEFAULT_TIMING) -> float:
    """A coarser, human-auditable bound:
        sum over serialized DMA worst cases
      + longest single compute chain (cores run concurrently)
    This over-approximates the exact bound (no overlap assumed between
    the DMA stream and the slowest core's compute chain).

    Domain note: this form is valid for the schedules our schedulers
    emit (compute phases depend only on DMA phases and earlier
    same-core computes, and parallel cores carry balanced chains).  It
    is NOT sound for arbitrary phase DAGs — a dependency chain can
    weave core0-compute -> DMA -> core1-compute and accumulate compute
    time from several cores, exceeding ``dma_total + longest_core``
    (tests/test_timing_properties.py exercises exactly this with
    randomized DAGs).  ``wcet_serial_bound`` is the always-sound
    fallback."""
    dma_total = sum(phase_wcet(p, hw, tp) for p in sched.phases
                    if p.kind != "compute")
    per_core = {}
    for p in sched.phases:
        if p.kind == "compute":
            per_core[p.resource] = per_core.get(p.resource, 0.0) \
                + phase_wcet(p, hw, tp)
    longest_core = max(per_core.values()) if per_core else 0.0
    return dma_total + longest_core


def wcet_serial_bound(sched: Schedule, hw: MultiVicConfig,
                      tp: TimingParams = DEFAULT_TIMING) -> float:
    """Full-serialization bound: the sum of every phase's worst case.

    Sound for ANY well-formed phase DAG: list scheduling can only start
    phases earlier than executing the list back-to-back, so by
    induction ``finish(i) <= sum_{j<=i} wcet(j)``.  Much coarser than
    ``wcet_closed_form`` (it grants no parallelism at all) but free of
    that bound's structural assumptions — the outer slice of the
    randomized-DAG WCET sandwich."""
    return sum(phase_wcet(p, hw, tp) for p in sched.phases)


def jitter_bound(sched: Schedule, tp: TimingParams = DEFAULT_TIMING):
    """Max possible spread (WCET - BCET) — all of it is DDR4 jitter,
    by construction: n_dma_bursts * worst_extra."""
    n_dma = sum(1 for p in sched.phases if p.kind != "compute")
    return n_dma * tp.dma_worst_extra
