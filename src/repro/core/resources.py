"""FPGA resource model (paper Fig. 5): LUT / FF / BRAM / DSP per
configuration and per component.

The paper publishes bar charts, not numbers; this model uses public
per-component estimates (Ibex ~4k LUT [PATMOS'17]; Vicuna LUT/DSP scale
with the multiplier width [ECRTS'21]; BRAM36 from SPM capacity; Xilinx
DDR4 MIG ~30k LUT) and reproduces the paper's qualitative findings:
 * total resources grow with core count (each core adds an Ibex + ISPM),
 * DSP count is roughly flat across variants (many small ~ few large),
 * worker cores + scratchpads dominate; the management core is tiny.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.multivic_paper import KIB, MultiVicConfig

IBEX_LUT, IBEX_FF = 4_000, 2_600
VICUNA_LUT_BASE, VICUNA_LUT_PER_MULBIT = 6_000, 14.0
VICUNA_FF_BASE, VICUNA_FF_PER_MULBIT = 4_000, 8.0
DSP_PER_MULBIT = 0.25              # DSP48s per multiplier bit (fp32 MACs)
BRAM36_BYTES = 4_608               # 36 Kib
DMA_LUT, DMA_FF = 3_000, 2_000
XBAR_LUT_PER_PORT = 700
DDR4_MIG_LUT, DDR4_MIG_FF, DDR4_MIG_BRAM = 30_000, 25_000, 26
TIMER_LUT = 500


def _brams(nbytes: int) -> int:
    return max(1, (nbytes + BRAM36_BYTES - 1) // BRAM36_BYTES)


def component_resources(hw: MultiVicConfig) -> Dict[str, Dict[str, float]]:
    worker_lut = (IBEX_LUT + VICUNA_LUT_BASE
                  + VICUNA_LUT_PER_MULBIT * hw.vicuna.mul_width_bits)
    worker_ff = (IBEX_FF + VICUNA_FF_BASE
                 + VICUNA_FF_PER_MULBIT * hw.vicuna.mul_width_bits)
    worker_dsp = DSP_PER_MULBIT * hw.vicuna.mul_width_bits
    worker_bram = _brams(hw.data_spm_bytes) + _brams(hw.insn_spm_bytes)
    W = hw.num_worker_cores
    ports = 2 * W + 2
    comps = {
        "workers": {
            "lut": W * worker_lut, "ff": W * worker_ff,
            "dsp": W * worker_dsp, "bram": W * worker_bram,
        },
        "mgmt_core": {
            "lut": IBEX_LUT + TIMER_LUT, "ff": IBEX_FF, "dsp": 0,
            "bram": _brams(hw.mgmt_insn_spm_bytes)
            + _brams(hw.mgmt_data_spm_bytes),
        },
        "dma_xbar": {
            "lut": DMA_LUT + XBAR_LUT_PER_PORT * ports, "ff": DMA_FF,
            "dsp": 0, "bram": 2,
        },
        "ddr4_ctrl": {
            "lut": DDR4_MIG_LUT, "ff": DDR4_MIG_FF, "dsp": 3,
            "bram": DDR4_MIG_BRAM,
        },
    }
    return comps


def total_resources(hw: MultiVicConfig) -> Dict[str, float]:
    tot: Dict[str, float] = {"lut": 0, "ff": 0, "dsp": 0, "bram": 0}
    for comp in component_resources(hw).values():
        for k in tot:
            tot[k] += comp[k]
    return tot
