"""Deterministic per-phase timing model for the MultiVic hardware.

Models, at cycle granularity (benchmark clock):

* worker-core compute: Vicuna vector pipeline issuing VL-element vector
  ops processed ``mul_width`` bits per cycle, vector loads from the
  dual-port SPM at ``spm_port_bytes`` per cycle, plus Ibex scalar-loop
  overhead per vector chunk and a reduction/store epilogue per output
  element (paper §4.3's inner loop).
* DMA: DDR4 with a fixed per-burst setup latency, a sustained
  bytes/cycle rate, and a bounded *jitter* term for row-miss/refresh —
  the sole source of execution-time variability in the system
  (paper §3.1).  The WCET model charges the full worst-case for every
  burst; the simulator draws jitter uniformly in [0, worst].

The free constants are CALIBRATED against the paper's two published
absolute cycle counts (Octa / Hexadeca medians, §5.1) — see
``tests/test_paper_validation.py``.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.multivic_paper import (DDR4_BASE_LATENCY,
                                          DDR4_BYTES_PER_CYCLE,
                                          DDR4_WORST_EXTRA_LATENCY,
                                          ELEM_BYTES, MultiVicConfig)
from repro.core.schedule import Phase


@dataclass(frozen=True)
class TimingParams:
    spm_port_bytes: float = 1.50697   # SPM load bandwidth per cycle
    loop_overhead: float = 19.9415    # Ibex issue + stripmine per chunk
    epilogue_cycles: float = 32.0     # reduce+store per output element
    dma_base_latency: float = DDR4_BASE_LATENCY
    dma_bytes_per_cycle: float = DDR4_BYTES_PER_CYCLE
    dma_worst_extra: float = DDR4_WORST_EXTRA_LATENCY
    mgmt_issue_cycles: float = 20.0   # mgmt-core cost to issue a phase


# Constants calibrated against the paper's two published medians (Octa
# 728,548,804 and Hexadeca 548,343,601 cycles for the 1024^3 matmul,
# §5.1); the inner loop is output-vectorized (stream B-chunk, broadcast
# A scalar — the per-chunk fixed cost absorbs the scalar load).  See
# benchmarks/bench_fig4_matmul.py and tests/test_paper_validation.py.
DEFAULT_TIMING = TimingParams()


def compute_cycles(ph: Phase, hw: MultiVicConfig,
                   tp: TimingParams = DEFAULT_TIMING) -> float:
    """Cycle count of one compute phase on a worker core."""
    assert ph.kind == "compute"
    vl_elems = hw.vicuna.vreg_bits // (8 * ELEM_BYTES)
    mac_cycles_per_chunk = hw.vicuna.vreg_bits / hw.vicuna.mul_width_bits
    load_cycles_per_chunk = vl_elems * ELEM_BYTES / tp.spm_port_bytes
    per_chunk = load_cycles_per_chunk + mac_cycles_per_chunk \
        + tp.loop_overhead
    return ph.vec_chunks * per_chunk + ph.elems * tp.epilogue_cycles


def dma_cycles(ph: Phase, tp: TimingParams = DEFAULT_TIMING,
               jitter: float = 0.0) -> float:
    """Cycle count of one DMA burst.  jitter in [0, 1] scales the
    worst-case extra latency (0 = best case, 1 = WCET)."""
    assert ph.kind in ("dma_load", "dma_store")
    return (tp.dma_base_latency + ph.bytes_moved / tp.dma_bytes_per_cycle
            + jitter * tp.dma_worst_extra)


def phase_wcet(ph: Phase, hw: MultiVicConfig,
               tp: TimingParams = DEFAULT_TIMING) -> float:
    """Worst-case duration of a single phase (compositional unit)."""
    if ph.kind == "compute":
        return compute_cycles(ph, hw, tp)
    return dma_cycles(ph, tp, jitter=1.0) + tp.mgmt_issue_cycles


def phase_best(ph: Phase, hw: MultiVicConfig,
               tp: TimingParams = DEFAULT_TIMING) -> float:
    if ph.kind == "compute":
        return compute_cycles(ph, hw, tp)
    return dma_cycles(ph, tp, jitter=0.0) + tp.mgmt_issue_cycles
