"""MultiVic -> TPU bridge: the paper's execution model instantiated on
the target hardware (v5e-class chip / pod constants from the
assignment).

Scale mapping (DESIGN.md §2):
    worker core + Vicuna      -> TPU core (MXU)
    data scratchpad           -> VMEM (software-managed, BlockSpec-tiled)
    management core + DMA     -> Pallas grid pipeline / XLA SPMD program
    DDR4                      -> HBM;  TileLink -> ICI collectives

`tpu_matmul_schedule` builds the same static Schedule IR the paper core
uses, but with TPU phase costs: HBM->VMEM tile DMAs double-buffered
against MXU tile compute; the per-phase WCET uses worst-case effective
bandwidths, giving a deterministic per-step latency bound — the
time-predictability claim carried to the datacenter target.  The
serving runtime (launch/serve.py) prints these bounds next to measured
step times.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.schedule import DMA, Schedule, core_resource


@dataclass(frozen=True)
class TPUChip:
    peak_flops: float = 197e12       # bf16
    hbm_bw: float = 819e9            # bytes/s
    vmem_bytes: int = 128 * 1024 * 1024
    ici_bw: float = 50e9             # per link
    # worst-case derates for WCET (DMA contention, MXU pipeline bubbles)
    worst_hbm_derate: float = 0.8
    worst_mxu_eff: float = 0.85


V5E = TPUChip()


def tpu_matmul_schedule(m: int, k: int, n: int, *, n_devices: int = 1,
                        tile_m: int = 512, tile_n: int = 512,
                        elem_bytes: int = 2,
                        chip: TPUChip = V5E) -> Schedule:
    """B-stationary blocked matmul on one or more TPU 'workers'.

    N is partitioned across devices (the paper's B-column blocks);
    within a device, (tile_m x k) A-tiles stream HBM->VMEM double-
    buffered against MXU compute, C tiles stream back — the identical
    dataflow to the paper's §4.3 at a 10^4x bandwidth scale.
    """
    assert n % n_devices == 0
    n_local = n // n_devices
    tiles_m = math.ceil(m / tile_m)
    tiles_n = math.ceil(n_local / tile_n)
    vmem_need = (k * tile_n + 2 * tile_m * k + 2 * tile_m * tile_n) \
        * elem_bytes
    sched = Schedule(meta={"kind": "tpu_matmul", "m": m, "k": k, "n": n,
                           "n_devices": n_devices, "tile_m": tile_m,
                           "tile_n": tile_n, "vmem_need": vmem_need,
                           "vmem_ok": vmem_need <= chip.vmem_bytes})
    for dev in range(n_devices):
        prev_comp = None
        for tn in range(tiles_n):
            b_load = sched.add(
                kind="dma_load", resource=DMA,
                bytes_moved=k * tile_n * elem_bytes, spm_core=dev,
                deps=(prev_comp,) if prev_comp is not None else (),
                tag=f"B[{tn}]->dev{dev}")
            for tm in range(tiles_m):
                a_load = sched.add(
                    kind="dma_load", resource=DMA,
                    bytes_moved=tile_m * k * elem_bytes,
                    deps=(b_load,), spm_core=dev,
                    tag=f"A[{tm}]->dev{dev}")
                comp = sched.add(
                    kind="compute", resource=core_resource(dev),
                    deps=(a_load,) + ((prev_comp,) if prev_comp else ()),
                    macs=tile_m * k * tile_n,
                    elems=tile_m * tile_n, spm_core=dev,
                    tag=f"C[{tm},{tn}]@dev{dev}")
                sched.add(
                    kind="dma_store", resource=DMA,
                    bytes_moved=tile_m * tile_n * elem_bytes,
                    deps=(comp,), spm_core=dev, tag=f"C[{tm},{tn}]->hbm")
                prev_comp = comp
    sched.validate_dag()
    sched.validate_interference_freedom()
    return sched


def serve_step_schedule(batch: int, d_model: int, n_params: int, *,
                        plan: dict, elem_bytes: int = 2,
                        chip: TPUChip = V5E) -> Schedule:
    """Static schedule for one decode step's weight pass, tiled by the
    SERVED plan.

    The serving runtime resolves a model plan (tuning.model) whose
    ``mm_bm``/``mm_bn`` pins are the decode matmul tiles; building the
    WCET schedule from those same pins is what makes the printed bound
    (and the deadline derived from it) track the plan actually served
    instead of a hand-picked constant.  Each generated token multiplies
    the [batch, d_model] activations against every weight matrix once:
    an effective [batch, d_model, 2*n_params/d_model] matmul.
    """
    n_eff = max(d_model, 2 * n_params // d_model)
    tile_m = max(1, min(int(plan["mm_bm"]), batch))
    tile_n = max(1, min(int(plan["mm_bn"]), n_eff))
    return tpu_matmul_schedule(batch, d_model, n_eff, tile_m=tile_m,
                               tile_n=tile_n, elem_bytes=elem_bytes,
                               chip=chip)


def tpu_phase_wcet(ph, chip: TPUChip = V5E) -> float:
    """Worst-case seconds for one TPU phase."""
    if ph.kind == "compute":
        return 2.0 * ph.macs / (chip.peak_flops * chip.worst_mxu_eff)
    return ph.bytes_moved / (chip.hbm_bw * chip.worst_hbm_derate)


def tpu_wcet(sched: Schedule, chip: TPUChip = V5E) -> float:
    """Compositional bound: serialized-DMA + slowest-core chain (the
    closed form from core/wcet.py with TPU phase costs)."""
    dma_total = sum(tpu_phase_wcet(p, chip) for p in sched.phases
                    if p.kind != "compute")
    per_core = {}
    for p in sched.phases:
        if p.kind == "compute":
            per_core[p.resource] = per_core.get(p.resource, 0.0) \
                + tpu_phase_wcet(p, chip)
    return dma_total + (max(per_core.values()) if per_core else 0.0)


def tpu_steady_state(sched: Schedule, chip: TPUChip = V5E) -> float:
    """Overlap-aware estimate: max(total DMA, slowest core compute) —
    what double buffering achieves when one side dominates."""
    dma_total = sum(tpu_phase_wcet(p, chip) for p in sched.phases
                    if p.kind != "compute")
    per_core = {}
    for p in sched.phases:
        if p.kind == "compute":
            per_core[p.resource] = per_core.get(p.resource, 0.0) \
                + tpu_phase_wcet(p, chip)
    comp = max(per_core.values()) if per_core else 0.0
    return max(dma_total, comp)
