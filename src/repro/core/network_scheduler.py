"""Whole-network static scheduling + time-triggered execution — the
paper's §4.3 extension ("for more complex programs such as entire
networks, time-triggered execution is preferable to facilitate timing
analyses"), implemented beyond the paper's single-matmul evaluation.

A feed-forward network (fully-connected / im2col'd conv layers) is a
sequence of GEMMs with deterministic dataflow.  We build one Schedule
covering all layers (per-layer B-stationary rounds; activations round-
trip DRAM between layers with a barrier) and derive a TIME-TRIGGERED
table: each phase gets a static release time equal to its start in the
all-worst-case list schedule.  Properties (tested):

  * schedulability: under ANY DDR4 jitter draw, every dependency
    completes before its consumer's release time,
  * the time-triggered makespan is constant up to the final phase's
    own jitter — end-to-end latency variance collapses to a single
    DMA burst's bound (vs. the event-driven execution whose makespan
    accumulates jitter),
  * makespan(event) <= makespan(time-triggered) <= WCET.

This is the scheduling layer the paper defers to its compiler future
work, expressed on the same IR and timing model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.multivic_paper import MultiVicConfig
from repro.core.schedule import Schedule
from repro.core.scheduler import MatmulProblem, build_matmul_schedule
from repro.core.simulator import SimResult
from repro.core.timing import (DEFAULT_TIMING, TimingParams, compute_cycles,
                               dma_cycles)


@dataclass(frozen=True)
class NetworkLayer:
    name: str
    m: int          # batch (im2col rows)
    k: int          # fan-in
    n: int          # fan-out


def mlp(batch: int, widths: Sequence[int]) -> List[NetworkLayer]:
    return [NetworkLayer(f"fc{i}", batch, widths[i], widths[i + 1])
            for i in range(len(widths) - 1)]


def build_network_schedule(hw: MultiVicConfig,
                           layers: Sequence[NetworkLayer],
                           rows_per_transfer: int = 4) -> Schedule:
    """Concatenate per-layer B-stationary schedules with a barrier on
    the previous layer's final store (activations live in DRAM between
    layers — deterministic dataflow, so this is still one static
    schedule the management core can execute)."""
    net = Schedule(meta={"hw": hw.name,
                         "layers": [vars(l) for l in layers]})
    barrier = None
    for layer in layers:
        sub = build_matmul_schedule(
            hw, MatmulProblem(layer.m, layer.k, layer.n),
            rows_per_transfer=rows_per_transfer)
        offset = len(net.phases)
        first_of_layer = offset
        for ph in sub.phases:
            deps = tuple(d + offset for d in ph.deps)
            if barrier is not None and not deps:
                deps = (barrier,)
            net.add(kind=ph.kind, resource=ph.resource, deps=deps,
                    bytes_moved=ph.bytes_moved, macs=ph.macs,
                    vec_chunks=ph.vec_chunks, elems=ph.elems,
                    spm_core=ph.spm_core,
                    tag=f"{layer.name}/{ph.tag}")
        barrier = len(net.phases) - 1   # last store of this layer
        del first_of_layer
    net.validate_dag()
    net.validate_interference_freedom()
    return net


# ---------------------------------------------------------------------------
# time-triggered table + executor


def release_times(sched: Schedule, hw: MultiVicConfig,
                  tp: TimingParams = DEFAULT_TIMING) -> np.ndarray:
    """Static per-phase release times = start times in the all-worst-
    case list schedule (the compile-time timetable)."""
    n = len(sched.phases)
    start = np.zeros(n)
    finish = np.zeros(n)
    res_free: Dict[str, float] = {}
    for ph in sched.phases:
        ready = max((finish[d] for d in ph.deps), default=0.0)
        s = max(ready, res_free.get(ph.resource, 0.0))
        if ph.kind == "compute":
            dur = compute_cycles(ph, hw, tp)
        else:
            dur = dma_cycles(ph, tp, jitter=1.0) + tp.mgmt_issue_cycles
        start[ph.pid] = s
        finish[ph.pid] = s + dur
        res_free[ph.resource] = s + dur
    return start


def simulate_time_triggered(sched: Schedule, hw: MultiVicConfig,
                            release: np.ndarray,
                            tp: TimingParams = DEFAULT_TIMING,
                            seed: int = 0) -> Tuple[SimResult, bool]:
    """Execute with phases held until their static release time.
    Returns (result, schedulable): schedulable is False if any
    dependency had not finished by its consumer's release (never
    happens for jitter <= worst case — property-tested)."""
    rng = np.random.default_rng(seed)
    n = len(sched.phases)
    finish = np.zeros(n)
    busy: Dict[str, float] = {}
    ok = True
    for ph in sched.phases:
        dep_done = max((finish[d] for d in ph.deps), default=0.0)
        if dep_done > release[ph.pid] + 1e-9:
            ok = False
        s = max(release[ph.pid], dep_done)
        if ph.kind == "compute":
            dur = compute_cycles(ph, hw, tp)
        else:
            dur = dma_cycles(ph, tp, jitter=float(rng.random())) \
                + tp.mgmt_issue_cycles
        finish[ph.pid] = s + dur
        busy[ph.resource] = busy.get(ph.resource, 0.0) + dur
    return SimResult(float(finish.max()), busy, n), ok


def tt_jitter_bound(tp: TimingParams = DEFAULT_TIMING) -> float:
    """Time-triggered end-to-end jitter collapses to the LAST phase's
    own duration jitter: one DMA burst's worst extra."""
    return tp.dma_worst_extra
