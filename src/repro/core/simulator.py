"""Discrete-event execution of a static Schedule.

Resources (DMA engine, each worker core) are serial; phases start when
their dependencies have finished AND their resource is free — i.e. list
scheduling in schedule order, which is exactly how the management core
issues the statically ordered phase list (paper §4.2).

The only stochastic element is DDR4 access jitter, drawn per DMA burst
from Uniform[0, worst_extra] with a seeded generator (paper §5.1: "the
fluctuations come from the fluctuating access times of the DDR4").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.configs.multivic_paper import MultiVicConfig
from repro.core.schedule import Schedule
from repro.core.timing import (DEFAULT_TIMING, TimingParams, compute_cycles,
                               dma_cycles)


@dataclass
class SimResult:
    total_cycles: float
    per_resource_busy: Dict[str, float]
    n_phases: int

    def seconds(self, clock_hz: float) -> float:
        return self.total_cycles / clock_hz


def simulate(sched: Schedule, hw: MultiVicConfig,
             tp: TimingParams = DEFAULT_TIMING,
             seed: Optional[int] = None,
             worst_case: bool = False,
             trace=None) -> SimResult:
    """Execute the schedule; when ``trace`` (a
    ``repro.obs.trace.TraceRecorder``) is given, every phase is recorded
    as a span on its resource's track with cycle timestamps — load the
    Chrome-trace export to see the schedule as a Gantt chart."""
    rng = np.random.default_rng(seed if seed is not None else 0)
    n = len(sched.phases)
    finish = np.zeros(n, dtype=np.float64)
    res_free: Dict[str, float] = {}
    busy: Dict[str, float] = {}

    for ph in sched.phases:
        ready = 0.0
        for d in ph.deps:
            ready = max(ready, finish[d])
        start = max(ready, res_free.get(ph.resource, 0.0))
        if ph.kind == "compute":
            dur = compute_cycles(ph, hw, tp)
        else:
            jit = 1.0 if worst_case else float(rng.random())
            dur = dma_cycles(ph, tp, jitter=jit) + tp.mgmt_issue_cycles
        end = start + dur
        finish[ph.pid] = end
        res_free[ph.resource] = end
        busy[ph.resource] = busy.get(ph.resource, 0.0) + dur
        if trace is not None:
            trace.add_span(ph.tag or f"{ph.kind}#{ph.pid}",
                           track=ph.resource, start=start, end=end,
                           cat=ph.kind, pid=ph.pid,
                           bytes_moved=ph.bytes_moved, macs=ph.macs)

    return SimResult(total_cycles=float(finish.max() if n else 0.0),
                     per_resource_busy=busy, n_phases=n)


def sweep_cycles(sched: Schedule, hw: MultiVicConfig, n_runs: int = 100,
                 tp: TimingParams = DEFAULT_TIMING,
                 seed0: int = 0) -> np.ndarray:
    """Total cycles of ``n_runs`` seeded executions (seeds
    ``seed0 .. seed0+n_runs-1``) — the sample vector behind both
    ``run_many`` and ``repro.obs.jitter.simulate_sweep``."""
    return np.array([
        simulate(sched, hw, tp, seed=seed0 + i).total_cycles
        for i in range(n_runs)])


def run_many(sched: Schedule, hw: MultiVicConfig, n_runs: int = 100,
             tp: TimingParams = DEFAULT_TIMING, seed0: int = 0):
    """The paper's measurement protocol: run the benchmark n times,
    report median and standard deviation of execution cycles."""
    cycles = sweep_cycles(sched, hw, n_runs=n_runs, tp=tp, seed0=seed0)
    return {
        "median": float(np.median(cycles)),
        "mean": float(cycles.mean()),
        "std": float(cycles.std()),
        "min": float(cycles.min()),
        "max": float(cycles.max()),
        "n": n_runs,
    }
