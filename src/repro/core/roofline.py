"""Paper Fig. 3: theoretical roofline per MultiVic configuration.

Compute ceiling: total multiplier lanes x 2 FLOPs (MAC) x F_max.
Memory slopes: aggregate scratchpad bandwidth (one dual-port SRAM port
per worker — this is the boundary the multi-core design SHIFTS) and the
shared DDR4 bandwidth (identical across configs).

The paper's observation reproduced here: all multi-core variants share
the Fast baseline's compute ceiling (total MUL width is constant at
1024 bits) but each added core adds a private SPM port, so the
SPM-bandwidth roofline moves right-up with core count, benefitting
data-intensive kernels with high reuse (§5.1).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.multivic_paper import (DDR4_BYTES_PER_CYCLE, ELEM_BYTES,
                                          MultiVicConfig)

SPM_PORT_BYTES_THEORETICAL = 4.0   # dual-port SRAM, one 32-bit read/cycle


def config_roofline(hw: MultiVicConfig, use_fmax: bool = True
                    ) -> Dict[str, float]:
    f = hw.fmax_hz if use_fmax else hw.benchmark_clock_hz
    lanes = hw.total_mul_width_bits / (8 * ELEM_BYTES)
    peak_flops = 2.0 * lanes * f
    spm_bw = hw.num_worker_cores * SPM_PORT_BYTES_THEORETICAL * f
    dram_bw = DDR4_BYTES_PER_CYCLE * f
    return {
        "config": hw.name,
        "fmax_mhz": f / 1e6,
        "peak_gflops": peak_flops / 1e9,
        "spm_bw_gbs": spm_bw / 1e9,
        "dram_bw_gbs": dram_bw / 1e9,
        # ridge points (FLOP/byte where the kernel becomes compute-bound)
        "ridge_spm": peak_flops / spm_bw,
        "ridge_dram": peak_flops / dram_bw,
    }


def attainable_gflops(hw: MultiVicConfig, arithmetic_intensity: float,
                      from_spm: bool = True) -> float:
    r = config_roofline(hw)
    bw = r["spm_bw_gbs"] if from_spm else r["dram_bw_gbs"]
    return min(r["peak_gflops"], arithmetic_intensity * bw)
