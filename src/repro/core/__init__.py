"""The paper's primary contribution, as a composable library:

  schedule.py   static-schedule IR + interference-freedom validation
  scheduler.py  compile-time blocked-matmul scheduler (paper §4.3)
  timing.py     calibrated cycle-accurate phase timing model
  simulator.py  discrete-event executor with seeded DDR4 jitter
  wcet.py       compositional WCET bounds (paper §3.1)
  roofline.py   paper Fig. 3 roofline model
  fmax.py       F_max model fitted to Tables 1-2
  resources.py  FPGA resource model (Fig. 5)
  tpu_mapping.py the MultiVic execution model on the TPU target
"""
from repro.core.schedule import DMA, Phase, Schedule, core_resource
from repro.core.scheduler import (MatmulProblem, build_matmul_schedule,
                                  schedule_totals, spm_plan)
from repro.core.simulator import SimResult, run_many, simulate
from repro.core.timing import DEFAULT_TIMING, TimingParams
from repro.core.wcet import jitter_bound, wcet, wcet_closed_form

__all__ = [
    "DMA", "Phase", "Schedule", "core_resource", "MatmulProblem",
    "build_matmul_schedule", "schedule_totals", "spm_plan", "SimResult",
    "run_many", "simulate", "DEFAULT_TIMING", "TimingParams",
    "jitter_bound", "wcet", "wcet_closed_form",
]
