"""Static-schedule IR.

The management core executes a compile-time-determined sequence of DMA
transfers and hands compute kernels to worker cores (paper §3/§4.2).
We model a schedule as a dependency DAG of *phases*; each phase runs on
exactly one serial resource (the DMA engine or one worker core).  The
absence of shared resources between workers — each phase touches only
its own core's scratchpad — is checked structurally by
``validate_interference_freedom``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

DMA = "dma"


def core_resource(core_id: int) -> str:
    return f"core{core_id}"


@dataclass(frozen=True)
class Phase:
    """One schedulable unit."""

    pid: int
    kind: str                 # dma_load | dma_store | compute
    resource: str             # DMA or core<i>
    deps: Tuple[int, ...]     # phase ids that must finish first
    # workload descriptors consumed by the timing model:
    bytes_moved: int = 0      # DMA phases: DRAM<->SPM traffic
    macs: int = 0             # compute phases: multiply-accumulates
    vec_chunks: int = 0       # number of vector-instruction chunks
    elems: int = 0            # output elements produced (epilogue cost)
    spm_core: Optional[int] = None   # which core's scratchpad is touched
    tag: str = ""


@dataclass
class Schedule:
    phases: List[Phase] = field(default_factory=list)
    meta: Dict = field(default_factory=dict)

    def add(self, **kw) -> int:
        pid = len(self.phases)
        kw.setdefault("deps", ())
        self.phases.append(Phase(pid=pid, **kw))
        return pid

    def __len__(self):
        return len(self.phases)

    # -- structural invariants (tested with hypothesis) ------------------

    def validate_dag(self) -> None:
        seen = set()
        for ph in self.phases:
            assert ph.pid not in seen
            for d in ph.deps:
                assert d < ph.pid, (
                    f"phase {ph.pid} depends on later phase {d}")
            seen.add(ph.pid)

    def validate_interference_freedom(self) -> None:
        """No worker core's phase may touch another core's scratchpad,
        and only DMA phases may move data between memories — the
        paper's freedom-from-interference property, checked on the IR."""
        for ph in self.phases:
            if ph.kind == "compute":
                cid = int(ph.resource.replace("core", ""))
                assert ph.spm_core in (None, cid), (
                    f"compute phase {ph.pid} on {ph.resource} touches "
                    f"SPM of core {ph.spm_core}")
                assert ph.bytes_moved == 0
            else:
                assert ph.resource == DMA, ph

    def resources(self) -> Sequence[str]:
        return sorted({p.resource for p in self.phases})
