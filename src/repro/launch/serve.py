"""Batched serving driver with MultiVic-style static step schedule.

Serving is where the paper's time-predictability matters most: each
decode step executes the same static program, so the runtime prints the
WCET bound per step (from core.tpu_mapping) next to the measured step
times and reports the observed jitter — the datacenter analogue of the
paper's Fig. 4 variability measurement.

The step program itself comes from a resolved **serving plan**
(tuning.model): prefill chunk sizes, scan-vs-unroll for the decode
layer loop, and the decode weight-pass tile pins.  Resolution follows
the kernel-wrapper precedence — explicit ``--chunk-q``/``--chunk-kv``
flags > the tuned plan cached by ``scripts/tune.py --model`` > shape-
safe defaults — and the WCET bound/deadline are built from the SAME
plan via ``serve_step_schedule``, so the printed bound tracks the plan
actually served.  Prefill and the decode step are AOT-compiled
(``compat.aot_compile``) with a donated KV cache before the timed
region, so every timed step — including the first — runs the compiled
program.

The WCET bound also becomes a *deadline*: every decode step is checked
against ``wcet * --deadline-slack`` (or an explicit ``--deadline-ms``)
and overruns walk the resilience ladder — record, then warn, then shed
(halve) the batch — so overload degrades on a pre-planned path instead
of queueing unboundedly (resilience.DeadlineMonitor; summary printed
next to the jitter stats).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --batch 4 --prompt-len 64 --gen 32

Set ``REPRO_TRACE=/path/serve.json`` to record the prefill and every
decode step as spans on the ``serve`` track (plus a per-step latency
counter and the ``deadline_*`` instants) and dump a Chrome trace at
exit — the same knob the trainer and the kernel-conformance harness
honor.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_config
from repro.launch.train import reduced_config
from repro.models import lm as lm_mod
from repro.models.lm import RunOptions
from repro.resilience.deadline import DeadlineMonitor
from repro.tuning.model import ModelProblem, resolve_model_plan
from repro.tuning.plan import plan_sig


def shed_batch(cfg, cache, tok, n_new: int, cache_len: int,
               windowed: bool = False):
    """Drop the tail of the batch (graceful degradation).

    Spec-driven, not heuristic: ``lm.cache_spec`` names the logical
    axes of every cache leaf, so we slice exactly the axis labelled
    ``batch`` (stacked-layer caches put it at index 1, behind the
    ``stack`` axis) and leave everything else alone."""
    b_old = tok.shape[0]
    assert 0 < n_new < b_old, (n_new, b_old)
    spec = lm_mod.cache_spec(cfg, b_old, cache_len, windowed)

    def shed(par, x):
        if "batch" not in par.axes:
            return x
        ax = par.axes.index("batch")
        return jax.lax.slice_in_dim(x, 0, n_new, axis=ax)

    return jax.tree.map(shed, spec, cache), tok[:n_new]


def plan_wcet_s(cfg, plan: dict, batch: int, n_params: int) -> float:
    """The per-step WCET bound for the decode weight pass under the
    served plan's tile pins — the single source for both the printed
    bound and the derived deadline (tested: changing the plan's pins
    must change this number)."""
    from repro.core.tpu_mapping import serve_step_schedule, tpu_wcet
    sched = serve_step_schedule(batch, cfg.d_model, n_params, plan=plan)
    return tpu_wcet(sched)


def compile_step_fns(cfg, params, batch, opts: RunOptions,
                     prompt_len: int):
    """AOT-compile prefill and the donated-cache decode step for the
    shapes in ``batch``; returns ``(prefill_c, step_c)`` ready to call.

    ``aot_compile`` populates nothing implicit — the returned compiled
    objects themselves must be called — which is exactly what keeps
    compilation out of the timed region (and off the jitter stats)."""
    prefill_j = jax.jit(lambda p, b: lm_mod.prefill(cfg, p, b, opts))
    step_j = compat.donated_jit(
        lambda p, c, t, i: lm_mod.decode_step(cfg, p, c, t, i, opts),
        donate_argnums=(1,))
    prefill_c = compat.aot_compile(prefill_j, params, batch)
    logits0, cache0 = prefill_c(params, batch)
    tok0 = jnp.argmax(logits0[:, :cfg.vocab_size], axis=-1)
    step_c = compat.aot_compile(step_j, params, cache0, tok0,
                                jnp.int32(prompt_len))
    return prefill_c, step_c


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--chunk-q", type=int, default=None,
                    help="explicit prefill q-chunk (overrides the "
                         "tuned serving plan)")
    ap.add_argument("--chunk-kv", type=int, default=None,
                    help="explicit prefill kv-chunk (overrides the "
                         "tuned serving plan)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="explicit per-step deadline; 0 = derive from "
                         "the WCET bound")
    ap.add_argument("--deadline-slack", type=float, default=50.0,
                    help="deadline = WCET bound x slack (the bound "
                         "targets the TPU mapping; on other backends "
                         "the slack absorbs the platform gap)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg, args)
    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G

    # serving plan: explicit flags > tuned cache entry > defaults
    problem = ModelProblem(
        args.arch, B, P, G,
        layers=0 if args.full else args.layers,
        d_model=args.d_model, vocab=args.vocab)
    resolved = resolve_model_plan(cfg, problem, {
        "chunk_q": args.chunk_q, "chunk_kv": args.chunk_kv})
    plan, plan_source = resolved["plan"], resolved["source"]
    opts = RunOptions(chunk_q=int(plan["chunk_q"]),
                      chunk_kv=int(plan["chunk_kv"]),
                      cache_len=total, remat=False,
                      decode_scan=bool(plan["decode_scan"]))

    key = jax.random.PRNGKey(0)
    params = lm_mod.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, P, cfg.d_model))

    trace_path = os.environ.get("REPRO_TRACE")
    rec = None
    if trace_path:
        from repro.obs import TraceRecorder
        rec = TraceRecorder(time_unit="us")

    # static-schedule WCET bound for the decode weight pass, built from
    # the SAME plan the steps will execute, computed up front so it can
    # serve as the step deadline
    n_p = lm_mod.param_count(cfg)
    wcet_s = plan_wcet_s(cfg, plan, B, n_p)
    deadline_s = (args.deadline_ms / 1e3 if args.deadline_ms > 0
                  else wcet_s * args.deadline_slack)
    dmon = DeadlineMonitor(deadline_s=deadline_s, trace=rec)

    # all compilation happens here, before anything is timed
    prefill_c, step_c = compile_step_fns(cfg, params, batch, opts, P)

    t0 = time.monotonic()
    logits, cache = jax.block_until_ready(prefill_c(params, batch))
    t_prefill = time.monotonic() - t0
    if rec is not None:
        rec.add_span("prefill", "serve", t0 * 1e6,
                     (t0 + t_prefill) * 1e6, cat="serve",
                     batch=B, prompt_len=P)

    out = []
    times = []
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
    for i in range(G):
        t1 = time.monotonic()
        logits, cache = step_c(params, cache, tok, jnp.int32(P + i))
        logits = jax.block_until_ready(logits)
        t2 = time.monotonic()
        times.append(t2 - t1)
        if rec is not None:
            rec.add_span(f"decode{i}", "serve", t1 * 1e6, t2 * 1e6,
                         cat="serve", pos=P + i)
            rec.counter("step_ms", (t2 - t1) * 1e3, track="serve")
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        out.append(np.asarray(tok))
        action = dmon.observe(i, t2 - t1)
        if action == "warn":
            print(f"deadline overrun at decode step {i}: "
                  f"{(t2 - t1) * 1e3:.2f} ms > "
                  f"{deadline_s * 1e3:.2f} ms")
        elif action == "shed" and tok.shape[0] > 1:
            n_new = tok.shape[0] // 2
            print(f"deadline ladder: shedding batch "
                  f"{tok.shape[0]} -> {n_new} at decode step {i}")
            cache, tok = shed_batch(cfg, cache, tok, n_new, total,
                                    opts.windowed_cache)
            # new batch shape = new program: re-AOT-compile outside the
            # per-step timing so the shed path stays compile-free too
            shed_batch_dict = {k: v[:n_new] for k, v in batch.items()}
            _, step_c = compile_step_fns(cfg, params, shed_batch_dict,
                                         opts, P)

    # AOT warm-up means step 0 is a real step: every sample counts
    times = np.array(times)
    print(f"serving plan [{plan_source}]: {plan_sig(plan)}")
    print(f"prefill: {t_prefill*1e3:.1f} ms for {B}x{P} tokens")
    print(f"decode:  median {np.median(times)*1e3:.2f} ms/step  "
          f"std {times.std()*1e3:.3f} ms  "
          f"jitter(max-min) {(times.max()-times.min())*1e3:.3f} ms")
    shapes = {o.shape for o in out}
    if len(shapes) == 1:
        print(f"generated shape: {np.stack(out, 1).shape}")
    else:
        print(f"generated: {len(out)} steps, batch shed to "
              f"{out[-1].shape[0]} (started at {B})")

    print(f"TPU-target WCET bound per step (weight pass, "
          f"plan tiles {plan['mm_bm']}x{plan['mm_bn']}): "
          f"{wcet_s*1e3:.3f} ms")
    s = dmon.summary()
    print(f"deadline: {s['deadline_s']*1e3:.3f} ms/step  "
          f"overruns {s['overruns']}/{len(times)}  "
          f"ladder record/warn/shed "
          f"{s['n_record']}/{s['n_warn']}/{s['n_shed']}  "
          f"worst overrun {s['worst_overrun_s']*1e3:.3f} ms")

    if rec is not None and rec.spans:
        from repro.obs import write_chrome_trace
        write_chrome_trace(rec, trace_path)
        print(f"trace: {len(rec.spans)} spans -> {trace_path}")


if __name__ == "__main__":
    main()
