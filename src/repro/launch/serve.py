"""Batched serving driver with MultiVic-style static step schedule.

Serving is where the paper's time-predictability matters most: each
decode step executes the same static program, so the runtime prints the
WCET bound per step (from core.tpu_mapping) next to the measured step
times and reports the observed jitter — the datacenter analogue of the
paper's Fig. 4 variability measurement.

The WCET bound also becomes a *deadline*: every decode step is checked
against ``wcet * --deadline-slack`` (or an explicit ``--deadline-ms``)
and overruns walk the resilience ladder — record, then warn, then shed
(halve) the batch — so overload degrades on a pre-planned path instead
of queueing unboundedly (resilience.DeadlineMonitor; summary printed
next to the jitter stats).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --batch 4 --prompt-len 64 --gen 32

Set ``REPRO_TRACE=/path/serve.json`` to record the prefill and every
decode step as spans on the ``serve`` track (plus a per-step latency
counter and the ``deadline_*`` instants) and dump a Chrome trace at
exit — the same knob the trainer and the kernel-conformance harness
honor.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.train import reduced_config
from repro.models import lm as lm_mod
from repro.models.lm import RunOptions
from repro.resilience.deadline import DeadlineMonitor


def shed_batch(cfg, cache, tok, n_new: int, cache_len: int,
               windowed: bool = False):
    """Drop the tail of the batch (graceful degradation).

    Spec-driven, not heuristic: ``lm.cache_spec`` names the logical
    axes of every cache leaf, so we slice exactly the axis labelled
    ``batch`` (stacked-layer caches put it at index 1, behind the
    ``stack`` axis) and leave everything else alone."""
    b_old = tok.shape[0]
    assert 0 < n_new < b_old, (n_new, b_old)
    spec = lm_mod.cache_spec(cfg, b_old, cache_len, windowed)

    def shed(par, x):
        if "batch" not in par.axes:
            return x
        ax = par.axes.index("batch")
        return jax.lax.slice_in_dim(x, 0, n_new, axis=ax)

    return jax.tree.map(shed, spec, cache), tok[:n_new]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="explicit per-step deadline; 0 = derive from "
                         "the WCET bound")
    ap.add_argument("--deadline-slack", type=float, default=50.0,
                    help="deadline = WCET bound x slack (the bound "
                         "targets the TPU mapping; on other backends "
                         "the slack absorbs the platform gap)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg, args)
    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    opts = RunOptions(chunk_q=32, chunk_kv=32, cache_len=total,
                      remat=False)

    key = jax.random.PRNGKey(0)
    params = lm_mod.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, P, cfg.d_model))

    prefill = jax.jit(lambda p, b: lm_mod.prefill(cfg, p, b, opts))
    step = jax.jit(lambda p, c, t, i: lm_mod.decode_step(
        cfg, p, c, t, i, opts), donate_argnums=(1,))

    trace_path = os.environ.get("REPRO_TRACE")
    rec = None
    if trace_path:
        from repro.obs import TraceRecorder
        rec = TraceRecorder(time_unit="us")

    # static-schedule WCET bound for the decode matmuls on the target,
    # computed up front so it can serve as the step deadline
    from repro.core.tpu_mapping import tpu_matmul_schedule, tpu_wcet
    n_p = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    sched = tpu_matmul_schedule(B, cfg.d_model, 2 * n_p // cfg.d_model,
                                tile_m=min(128, B) if B >= 8 else 8,
                                tile_n=512)
    wcet_s = tpu_wcet(sched)
    deadline_s = (args.deadline_ms / 1e3 if args.deadline_ms > 0
                  else wcet_s * args.deadline_slack)
    dmon = DeadlineMonitor(deadline_s=deadline_s, trace=rec)

    t0 = time.monotonic()
    logits, cache = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.monotonic() - t0
    if rec is not None:
        rec.add_span("prefill", "serve", t0 * 1e6,
                     (t0 + t_prefill) * 1e6, cat="serve",
                     batch=B, prompt_len=P)

    out = []
    times = []
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
    for i in range(G):
        t1 = time.monotonic()
        logits, cache = step(params, cache, tok, P + i)
        logits = jax.block_until_ready(logits)
        t2 = time.monotonic()
        times.append(t2 - t1)
        if rec is not None:
            rec.add_span(f"decode{i}", "serve", t1 * 1e6, t2 * 1e6,
                         cat="serve", pos=P + i)
            rec.counter("step_ms", (t2 - t1) * 1e3, track="serve")
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        out.append(np.asarray(tok))
        # deadline ladder (skip step 0: compile, already excluded from
        # the jitter stats below for the same reason)
        if i >= 1:
            action = dmon.observe(i, t2 - t1)
            if action == "warn":
                print(f"deadline overrun at decode step {i}: "
                      f"{(t2 - t1) * 1e3:.2f} ms > "
                      f"{deadline_s * 1e3:.2f} ms")
            elif action == "shed" and tok.shape[0] > 1:
                n_new = tok.shape[0] // 2
                print(f"deadline ladder: shedding batch "
                      f"{tok.shape[0]} -> {n_new} at decode step {i}")
                cache, tok = shed_batch(cfg, cache, tok, n_new, total,
                                        opts.windowed_cache)

    times = np.array(times[1:])   # drop first (compile)
    print(f"prefill: {t_prefill*1e3:.1f} ms for {B}x{P} tokens")
    print(f"decode:  median {np.median(times)*1e3:.2f} ms/step  "
          f"std {times.std()*1e3:.3f} ms  "
          f"jitter(max-min) {(times.max()-times.min())*1e3:.3f} ms")
    shapes = {o.shape for o in out}
    if len(shapes) == 1:
        print(f"generated shape: {np.stack(out, 1).shape}")
    else:
        print(f"generated: {len(out)} steps, batch shed to "
              f"{out[-1].shape[0]} (started at {B})")

    print(f"TPU-target WCET bound per step (weight pass): "
          f"{wcet_s*1e3:.3f} ms")
    s = dmon.summary()
    print(f"deadline: {s['deadline_s']*1e3:.3f} ms/step  "
          f"overruns {s['overruns']}/{len(times)}  "
          f"ladder record/warn/shed "
          f"{s['n_record']}/{s['n_warn']}/{s['n_shed']}  "
          f"worst overrun {s['worst_overrun_s']*1e3:.3f} ms")

    if rec is not None and rec.spans:
        from repro.obs import write_chrome_trace
        write_chrome_trace(rec, trace_path)
        print(f"trace: {len(rec.spans)} spans -> {trace_path}")


if __name__ == "__main__":
    main()
