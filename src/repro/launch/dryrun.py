"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory / cost / collective
analysis.  This is the proof that the distribution config is coherent
without real hardware (see DESIGN.md and EXPERIMENTS.md §Dry-run).

NOTE: the first two statements below must run before ANY other import —
jax locks the device count on first init, and the dry-run needs 512
placeholder host devices.  Do not set this flag globally.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k \
      [--multi-pod] [--out experiments/dryrun]
  python -m repro.launch.dryrun --all [--multi-pod both]   # orchestrator
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax

from repro.analysis.hlo import summarize_compiled
from repro.compat import cost_analysis
from repro.configs import SHAPES, TrainConfig, get_config, supported_shapes
from repro.configs.all_archs import ALL_ARCH_IDS
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, run_options
from repro.models import lm as lm_mod
from repro.models.lm import RunOptions
from repro.optim.adamw import make_train_step

OUT_DEFAULT = "experiments/dryrun"


def step_fn_for(cfg, shape, opts: RunOptions, variant: str = "baseline"):
    if shape.kind == "train":
        micro = 4 if "micro4" in variant else 0
        tstep = make_train_step(cfg, TrainConfig(microbatch=micro), opts)

        def train_step(params, opt_state, batch):
            return tstep(params, opt_state, batch)
        return train_step, (0, 1)        # donate params+opt

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return lm_mod.prefill(cfg, params, batch, opts)
        return prefill_step, ()

    def serve_step(params, cache, token, pos):
        return lm_mod.decode_step(cfg, params, cache, token, pos, opts)
    return serve_step, (1,)              # donate cache


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    opts = run_options(cfg, shape, mesh, variant)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "variant": variant,
        "status": "unknown",
    }
    t0 = time.time()
    try:
        step, donate = step_fn_for(cfg, shape, opts, variant)
        specs = input_specs(cfg, shape, mesh, variant)
        with mesh:
            lowered = jax.jit(step, donate_argnums=donate).lower(*specs)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        print(compiled.memory_analysis())
        ca = cost_analysis(compiled)
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        rec.update(summarize_compiled(compiled))
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    out_dir.mkdir(parents=True, exist_ok=True)
    prefix = "" if variant == "baseline" else f"{variant}__"
    fname = f"{prefix}{arch}__{shape_name}__{rec['mesh']}.json"
    (out_dir / fname).write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
          f"{rec['status']} ({rec['total_s']}s)")
    return rec


def all_cells(which_meshes=("single", "multi")):
    for arch in ALL_ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in supported_shapes(cfg):
            for m in which_meshes:
                yield arch, shape_name, m == "multi"


def orchestrate(args) -> int:
    """Run every cell in a subprocess (isolated jax state; one failure
    doesn't kill the sweep)."""
    out = pathlib.Path(args.out)
    meshes = {"single": ("single",), "multi": ("multi",),
              "both": ("single", "multi")}[args.multi_pod]
    failures = []
    for arch, shape_name, mp in all_cells(meshes):
        tag = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
        f = out / f"{tag}.json"
        if f.exists() and not args.force:
            rec = json.loads(f.read_text())
            if rec.get("status") == "ok":
                print(f"[skip] {tag} (cached ok)")
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape_name, "--out", args.out]
        if mp:
            cmd.append("--multi-pod")
        r = subprocess.run(cmd, env={**os.environ})
        if r.returncode != 0:
            failures.append(tag)
    if failures:
        print("FAILURES:", failures)
        return 1
    print("dry-run sweep complete")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", nargs="?", const="multi",
                    default="single",
                    choices=["single", "multi", "both"], dest="multi_pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=OUT_DEFAULT)
    args = ap.parse_args()
    if args.all:
        sys.exit(orchestrate(args))
    assert args.arch and args.shape, "--arch/--shape or --all"
    rec = run_cell(args.arch, args.shape, args.multi_pod == "multi",
                   pathlib.Path(args.out), args.variant)
    sys.exit(0 if rec["status"] == "ok" else 1)


if __name__ == "__main__":
    main()
