"""ShapeDtypeStruct input specs for every (architecture x shape) cell.

Same pattern as shannon/kernels: weak-type-correct, shardable stand-ins;
nothing is ever allocated for the full-size models.  ``input_specs``
returns the keyword arguments for the cell's step function:

  train   -> step(params, opt_state, batch)
  prefill -> step(params, batch)
  decode  -> step(params, cache, token, pos)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm as lm_mod
from repro.models.spec import shape_tree
from repro.optim.adamw import adamw_init_spec
from repro.sharding.rules import ShardingRules, make_rules


def _sds(shape, dtype, rules: ShardingRules, logical_axes):
    return jax.ShapeDtypeStruct(
        shape, jnp.dtype(dtype),
        sharding=rules.sharding_for(logical_axes, shape))


def rules_for(mesh, cfg: ModelConfig, shape: ShapeConfig,
              variant: str = "baseline") -> ShardingRules:
    """Build sharding rules, optionally applying optimization variants
    (the §Perf hillclimb levers; all semantics-preserving):

      serving_tp — inference weights stationary on the model axis only
                   (no per-token FSDP gathers); needs weights to fit
                   16-way (OK up to ~72B bf16 dense).
      seqpar     — Megatron-style sequence parallelism: the residual
                   stream (and therefore every remat-saved activation)
                   is sharded on the model axis between blocks.
      kvshard    — shard head_dim on the model axis when the (kv-)head
                   count doesn't divide it (removes the partitioner's
                   'involuntary full rematerialization' replication).

    Combine with '+': e.g. "seqpar+kvshard".
    """
    import dataclasses as _dc
    kind = shape.kind
    if shape.kind == "decode" and shape.global_batch == 1:
        kind = "long_decode"
    r = make_rules(mesh, kind, shape.global_batch)
    parts = set(variant.split("+")) if variant else {"baseline"}
    if "serving_tp" in parts and shape.kind in ("decode", "prefill"):
        r = _dc.replace(r, fsdp_axes=())
    if "seqpar" in parts:
        r = _dc.replace(r, act_seq_axes=r.tensor_axes)
    if "kvshard" in parts:
        r = _dc.replace(r, head_dim_axes=r.tensor_axes)
    return r


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                rules: ShardingRules) -> Dict:
    """Token/target (+ frontend stub) specs for train/prefill."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        # frames are the encoder input (conv frontend stubbed); decoder
        # sees seq/dec_len_ratio tokens.
        dec = max(256, S // cfg.encdec.dec_len_ratio)
        out = {
            "tokens": _sds((B, dec), jnp.int32, rules, ("batch", None)),
            "targets": _sds((B, dec), jnp.int32, rules, ("batch", None)),
            "frames": _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype), rules,
                           ("batch", None, None)),
        }
        return out
    out = {
        "tokens": _sds((B, S), jnp.int32, rules, ("batch", None)),
        "targets": _sds((B, S), jnp.int32, rules, ("batch", None)),
    }
    if cfg.frontend.kind == "patches" and cfg.frontend.num_positions:
        out["patch_embeds"] = _sds(
            (B, cfg.frontend.num_positions, cfg.d_model),
            jnp.dtype(cfg.dtype), rules, ("batch", None, None))
    return out


def decode_token_spec(cfg: ModelConfig, shape: ShapeConfig,
                      rules: ShardingRules):
    B = shape.global_batch
    return _sds((B,), jnp.int32, rules, ("batch",))


def params_specs(cfg: ModelConfig, rules: ShardingRules):
    return shape_tree(lm_mod.model_spec(cfg), rules)


def opt_specs(cfg: ModelConfig, rules: ShardingRules):
    return shape_tree(adamw_init_spec(lm_mod.model_spec(cfg)), rules)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                rules: ShardingRules, variant: str = "baseline"):
    cache_len = shape.seq_len
    windowed = "wincache" in variant
    return shape_tree(
        lm_mod.cache_spec(cfg, shape.global_batch, cache_len, windowed),
        rules)


def act_shardings(cfg: ModelConfig, shape: ShapeConfig,
                  rules: ShardingRules) -> dict:
    """NamedShardings for the activation sharding constraints (see
    models.lm._wsc): residual stream, loss logits, KV-cache buffers.
    Under the `seqpar` variant the residual stream's sequence dim is
    sharded on the model axis (rules.act_seq_axes)."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    out = {
        "x": rules.sharding_for(("batch", "seq", None), (B, S, d)),
        "logits": rules.sharding_for(("batch", None, "vocab"),
                                     (B, 1, cfg.padded_vocab)),
    }
    if rules.act_seq_axes:
        # full Megatron-SP: block outputs constrained seq-sharded so the
        # backward emits reduce-scatters instead of dx all-reduces
        out["x_sp"] = rules.sharding_for(
            ("batch", "seq", None), (B, S, d))
    if cfg.attention is not None:
        a = cfg.attention
        out["kv"] = rules.sharding_for(
            ("batch", "kv_seq", "kv_heads", None),
            (B, S, a.num_kv_heads, a.head_dim))
    return out


def run_options(cfg: ModelConfig, shape: ShapeConfig, mesh,
                variant: str = "baseline",
                **overrides) -> "lm_mod.RunOptions":
    rules = rules_for(mesh, cfg, shape, variant)
    kw = dict(shardings=act_shardings(cfg, shape, rules))
    if "moe_gather" in variant:
        kw["moe_impl"] = "gather"
    if "moe_ep" in variant:
        kw["moe_impl"] = "ep"
    if "wincache" in variant:
        kw["windowed_cache"] = True
    kw.update(overrides)
    return lm_mod.RunOptions(**kw)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                variant: str = "baseline") -> Tuple:
    """Everything the cell's step function needs, as ShapeDtypeStructs."""
    rules = rules_for(mesh, cfg, shape, variant)
    if shape.kind == "train":
        return (params_specs(cfg, rules), opt_specs(cfg, rules),
                batch_specs(cfg, shape, rules))
    if shape.kind == "prefill":
        return (params_specs(cfg, rules), batch_specs(cfg, shape, rules))
    # decode
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return (params_specs(cfg, rules),
            cache_specs(cfg, shape, rules, variant),
            decode_token_spec(cfg, shape, rules), pos)
