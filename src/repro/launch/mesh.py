"""Production mesh definitions.

Defined as FUNCTIONS (not module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS before first jax init, while smoke tests must see a
single real CPU device.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.compat import auto_axis_types, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis
    is pure data parallelism (gradient all-reduce over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


def make_host_mesh():
    """Degenerate 1x1 mesh on whatever single device is present —
    smoke tests and CPU examples."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))


def mesh_tag(mesh) -> str:
    return "x".join(f"{n}={mesh.shape[n]}" for n in mesh.axis_names)
