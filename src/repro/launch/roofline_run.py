"""Roofline runner: lower + compile every cell's cost PIECES on the
single-pod production mesh, compose totals (piece x multiplier), add
the analytic MODEL_FLOPS, and emit the three roofline terms.

First two statements must precede any other import (jax device count).

Usage:
  python -m repro.launch.roofline_run --arch qwen2-72b --shape train_4k
  python -m repro.launch.roofline_run --all
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax

from repro.analysis.flops import active_param_count, model_flops, param_count
from repro.analysis.hlo import summarize_compiled
from repro.analysis.pieces import cost_pieces
from repro.analysis.roofline import compose_pieces, roofline_terms
from repro.configs import SHAPES, get_config, supported_shapes
from repro.configs.all_archs import ALL_ARCH_IDS
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import rules_for, run_options

OUT_DEFAULT = "experiments/roofline"


def run_cell(arch: str, shape_name: str, out_dir: pathlib.Path,
             variant: str = "baseline", opt_overrides=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    rules = rules_for(mesh, cfg, shape, variant)
    opts = run_options(cfg, shape, mesh, variant,
                       **(opt_overrides or {}))
    rec = {"arch": arch, "shape": shape_name, "mesh": "16x16",
           "chips": 256, "variant": variant, "status": "unknown",
           "pieces": []}
    t0 = time.time()
    try:
        pieces = cost_pieces(cfg, shape, rules, opts)
        for pc in pieces:
            t1 = time.time()
            with mesh:
                compiled = jax.jit(pc.fn).lower(*pc.specs).compile()
            prec = {"name": pc.name, "multiplier": pc.multiplier,
                    "compile_s": round(time.time() - t1, 2)}
            prec.update(summarize_compiled(compiled))
            rec["pieces"].append(prec)
        comp = compose_pieces(rec["pieces"])
        rec["composed"] = comp
        from repro.analysis.bytes_model import analytic_bytes
        wsh = 16 if "serving_tp" in variant else 0
        ab = analytic_bytes(cfg, shape, weight_shards=wsh)
        rec["analytic_bytes"] = ab
        # analytic (flash-tiled) bytes determine the memory term; the
        # HLO-composed bytes are reported as the unfused upper bound.
        rec["terms"] = roofline_terms(comp["flops"], ab["total"],
                                      comp["collective_bytes"])
        rec["terms_hlo_bytes"] = roofline_terms(
            comp["flops"], comp["bytes_accessed"],
            comp["collective_bytes"])
        mf = model_flops(cfg, shape)
        rec["model_flops_global"] = mf
        rec["model_flops_per_dev"] = mf / 256
        rec["params_total"] = param_count(cfg)
        rec["params_active"] = active_param_count(cfg)
        rec["useful_ratio"] = (mf / 256) / comp["flops"] \
            if comp["flops"] else 0.0
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    out_dir.mkdir(parents=True, exist_ok=True)
    prefix = "" if variant == "baseline" else f"{variant}__"
    (out_dir / f"{prefix}{arch}__{shape_name}.json").write_text(
        json.dumps(rec, indent=1))
    t = rec.get("terms", {})
    print(f"[roofline] {arch} x {shape_name}: {rec['status']} "
          f"dominant={t.get('dominant')} bound={t.get('bound_s', 0):.4f}s "
          f"({rec['total_s']}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=OUT_DEFAULT)
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    if args.all:
        failures = []
        for arch in ALL_ARCH_IDS:
            for shape_name in supported_shapes(get_config(arch)):
                f = out / f"{arch}__{shape_name}.json"
                if f.exists() and not args.force:
                    if json.loads(f.read_text()).get("status") == "ok":
                        print(f"[skip] {arch} x {shape_name}")
                        continue
                cmd = [sys.executable, "-m", "repro.launch.roofline_run",
                       "--arch", arch, "--shape", shape_name,
                       "--out", args.out]
                if subprocess.run(cmd, env={**os.environ}).returncode:
                    failures.append((arch, shape_name))
        print("FAILURES:" if failures else "roofline sweep complete",
              failures or "")
        sys.exit(1 if failures else 0)
    rec = run_cell(args.arch, args.shape, out, args.variant)
    sys.exit(0 if rec["status"] == "ok" else 1)


if __name__ == "__main__":
    main()
