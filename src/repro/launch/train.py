"""Training launcher.

CPU-friendly by default (reduced configs); pass --full to build the
published architecture sizes (requires a real TPU mesh).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 100 --d-model 128 --layers 2 --seq 128 --batch 8

Set ``REPRO_TRACE=/path/train.json`` to record every training step as
a span on the ``trainer`` track and dump a Chrome trace at exit (same
knob the kernel-conformance harness honors).
"""
from __future__ import annotations

import argparse
import os

from repro.configs import TrainConfig, get_config, reduce_config
from repro.data.pipeline import DataConfig
from repro.models.lm import RunOptions
from repro.runtime.trainer import Trainer


def reduced_config(cfg, args):
    """CLI shim over configs.reduce_config (the shared shrink the
    serving autotuner keys its plans on)."""
    return reduce_config(cfg, layers=args.layers, d_model=args.d_model,
                         vocab=args.vocab)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the published architecture size")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="explicit per-step deadline; 0 = derive from "
                         "the WCET bound")
    ap.add_argument("--deadline-slack", type=float, default=50.0,
                    help="deadline = WCET bound x slack (the bound "
                         "targets the TPU mapping; on other backends "
                         "the slack absorbs the platform gap)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg, args)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps,
                       microbatch=args.microbatch)
    dcfg = DataConfig(vocab_size=cfg.vocab_size,
                      global_batch=args.batch, seq_len=args.seq)
    opts = RunOptions(chunk_q=64, chunk_kv=64, loss_chunk=64,
                      remat=False)

    trace_path = os.environ.get("REPRO_TRACE")
    rec = None
    if trace_path:
        from repro.obs import TraceRecorder
        rec = TraceRecorder(time_unit="us")

    # WCET-derived step deadline, same recipe as serving: the weight
    # pass over B*S tokens, tiled by the resolved kernel plan; the
    # forward+backward pass streams each weight ~3x (fwd, grad-wrt-
    # input, grad-wrt-weight), hence the 3x on the one-pass bound.
    from repro.core.tpu_mapping import serve_step_schedule, tpu_wcet
    from repro.models.lm import param_count
    from repro.tuning.model import ModelProblem, kernel_pins
    prob = ModelProblem(args.arch, args.batch * args.seq, args.seq,
                        1, layers=0 if args.full else args.layers,
                        d_model=args.d_model, vocab=args.vocab)
    sched = serve_step_schedule(args.batch * args.seq, cfg.d_model,
                                param_count(cfg),
                                plan=kernel_pins(cfg, prob))
    wcet_s = 3.0 * tpu_wcet(sched)
    deadline_s = (args.deadline_ms / 1e3 if args.deadline_ms > 0
                  else wcet_s * args.deadline_slack)
    from repro.resilience.deadline import DeadlineMonitor
    dmon = DeadlineMonitor(deadline_s=deadline_s, trace=rec)

    tr = Trainer(cfg, tcfg, dcfg, ckpt_dir=args.ckpt_dir, opts=opts,
                 trace=rec, deadline=dmon)
    hist = tr.run(args.steps)
    print(f"first loss {hist['loss'][0]:.4f} -> last "
          f"{hist['loss'][-1]:.4f} in {hist['wall_s'][0]:.1f}s")
    print(f"TPU-target WCET bound per step (fwd+bwd weight passes): "
          f"{wcet_s*1e3:.3f} ms")
    s = dmon.summary()
    print(f"deadline: {s['deadline_s']*1e3:.3f} ms/step  "
          f"overruns {s['overruns']}  ladder record/warn/shed "
          f"{s['n_record']}/{s['n_warn']}/{s['n_shed']}  "
          f"worst overrun {s['worst_overrun_s']*1e3:.3f} ms")

    if rec is not None and rec.spans:
        from repro.obs import write_chrome_trace
        write_chrome_trace(rec, trace_path)
        print(f"trace: {len(rec.spans)} spans -> {trace_path}")


if __name__ == "__main__":
    main()
