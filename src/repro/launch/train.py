"""Training launcher.

CPU-friendly by default (reduced configs); pass --full to build the
published architecture sizes (requires a real TPU mesh).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 100 --d-model 128 --layers 2 --seq 128 --batch 8

Set ``REPRO_TRACE=/path/train.json`` to record every training step as
a span on the ``trainer`` track and dump a Chrome trace at exit (same
knob the kernel-conformance harness honors).
"""
from __future__ import annotations

import argparse
import dataclasses
import os

from repro.configs import TrainConfig, get_config
from repro.data.pipeline import DataConfig
from repro.models.lm import RunOptions
from repro.runtime.trainer import Trainer


def reduced_config(cfg, args):
    kw = dict(num_layers=args.layers, d_model=args.d_model,
              d_ff=args.d_model * 3, vocab_size=args.vocab,
              vocab_pad_multiple=64)
    if cfg.attention:
        kw["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4, num_kv_heads=2, head_dim=32)
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            expert_ff=64, group_size=32,
            shared_expert_ff=64 if cfg.moe.shared_expert_ff else 0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk_size=32)
        kw["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4, num_kv_heads=4, head_dim=64)
    if cfg.rwkv:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32,
                                         chunk_size=32)
    if cfg.encdec:
        kw["encdec"] = dataclasses.replace(cfg.encdec, encoder_layers=2)
    return dataclasses.replace(cfg, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the published architecture size")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg, args)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps,
                       microbatch=args.microbatch)
    dcfg = DataConfig(vocab_size=cfg.vocab_size,
                      global_batch=args.batch, seq_len=args.seq)
    opts = RunOptions(chunk_q=64, chunk_kv=64, loss_chunk=64,
                      remat=False)

    trace_path = os.environ.get("REPRO_TRACE")
    rec = None
    if trace_path:
        from repro.obs import TraceRecorder
        rec = TraceRecorder(time_unit="us")

    tr = Trainer(cfg, tcfg, dcfg, ckpt_dir=args.ckpt_dir, opts=opts,
                 trace=rec)
    hist = tr.run(args.steps)
    print(f"first loss {hist['loss'][0]:.4f} -> last "
          f"{hist['loss'][-1]:.4f} in {hist['wall_s'][0]:.1f}s")

    if rec is not None and rec.spans:
        from repro.obs import write_chrome_trace
        write_chrome_trace(rec, trace_path)
        print(f"trace: {len(rec.spans)} spans -> {trace_path}")


if __name__ == "__main__":
    main()
