"""The offline autotuner: enumerate -> prune -> measure -> persist.

Pipeline for one (kernel, problem):

1. enumerate candidate block plans (candidates.py),
2. drop VMEM-infeasible ones and rank the rest with the analytic
   roofline model (cost_model.py) — only the top ``max_candidates``
   (always including the default plan) are ever measured,
3. measure the survivors under an ``obs.TraceRecorder`` and select by
   the jitter-aware objective (measure.py: p99 with CoV tie-break),
4. persist the winner to the JSON plan cache (plan_cache.py) so every
   later call — CLI, benchmark, or kernel wrapper — reuses it with
   zero measurements.

Measurement inputs are deterministic (fixed PRNG keys derived from the
problem), mirroring the conformance harness, so re-tuning the same
problem on the same machine measures the same computation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from repro.obs import JitterStats, TraceRecorder
from repro.tuning.candidates import defaults_for, enumerate_candidates
from repro.tuning.cost_model import analytic_cost_s, feasibility
from repro.tuning.measure import measure_callable, select_plan
from repro.tuning.plan import (AttentionProblem, MatmulProblem, Plan,
                               Problem, WkvProblem, plan_sig)
from repro.tuning.plan_cache import PlanCache, cache_key


@dataclass(frozen=True)
class TuneResult:
    kernel: str
    problem: Problem
    plan: Plan
    source: str                       # "cache" | "measured"
    key: str
    measured: int                     # timed reps performed (0 = warm)
    candidates: int                   # enumerated
    feasible: int                     # after the VMEM check
    pruned_to: int                    # actually measured plans
    stats: Optional[JitterStats] = None


# ------------------------------------------------------ input builders
# jax imports stay inside the builders: candidate enumeration, cost
# modeling and cache lookups must work without touching jax at all.

def make_runner(kernel: str, problem: Problem, plan: Plan,
                interpret: Optional[bool] = None) -> Callable[[], None]:
    """A zero-arg thunk running the kernel once on deterministic
    inputs, blocking on the result (what measure_callable times)."""
    import jax
    import jax.numpy as jnp

    if kernel == "spm_matmul":
        from repro.kernels.spm_matmul.ops import matmul
        p: MatmulProblem = problem
        dt = jnp.dtype(p.dtype)
        ka, kb = jax.random.split(jax.random.PRNGKey(p.m + p.k + p.n))
        a = jax.random.normal(ka, (p.m, p.k), jnp.float32).astype(dt)
        b = jax.random.normal(kb, (p.k, p.n), jnp.float32).astype(dt)
        kw = dict(plan)
        return lambda: jax.block_until_ready(
            matmul(a, b, interpret=interpret, **kw))

    if kernel == "flash_attention":
        from repro.kernels.flash_attention.ops import attention
        ap: AttentionProblem = problem
        dt = jnp.dtype(ap.dtype)
        ks = jax.random.split(
            jax.random.PRNGKey(ap.seq_q + ap.heads + ap.head_dim), 3)
        q = jax.random.normal(
            ks[0], (ap.batch, ap.seq_q, ap.heads, ap.head_dim),
            jnp.float32).astype(dt)
        k = jax.random.normal(
            ks[1], (ap.batch, ap.seq_k, ap.kv_heads, ap.head_dim),
            jnp.float32).astype(dt)
        v = jax.random.normal(
            ks[2], (ap.batch, ap.seq_k, ap.kv_heads, ap.head_dim),
            jnp.float32).astype(dt)
        kw = dict(plan)
        return lambda: jax.block_until_ready(
            attention(q, k, v, causal=ap.causal, window=ap.window,
                      interpret=interpret, **kw))

    if kernel == "wkv6":
        from repro.kernels.wkv6.ops import wkv
        wp: WkvProblem = problem
        ks = jax.random.split(
            jax.random.PRNGKey(wp.seq + wp.key_dim), 5)
        shape = (wp.batch, wp.seq, wp.heads, wp.key_dim)
        r = jax.random.normal(ks[0], shape) * 0.5
        k = jax.random.normal(ks[1], shape) * 0.5
        v = jax.random.normal(ks[2], shape) * 0.5
        w_log = -jnp.exp(jax.random.normal(ks[3], shape) * 0.8 - 2.0)
        u = jax.random.normal(ks[4], (wp.heads, wp.key_dim)) * 0.3
        kw = dict(plan)
        return lambda: jax.block_until_ready(
            wkv(r, k, v, w_log, u, interpret=interpret, **kw))

    raise KeyError(f"unknown kernel {kernel!r}")


# -------------------------------------------------------------- tuning

def shortlist(kernel: str, problem: Problem,
              max_candidates: int = 4) -> Tuple[List[Plan], int, int]:
    """Enumerate, VMEM-filter, rank analytically; returns the plans to
    measure (default always included) plus (enumerated, feasible)."""
    cands = enumerate_candidates(kernel, problem)
    feas = [c for c in cands if feasibility(kernel, problem, c).fits]
    ranked = sorted(feas, key=lambda c: (
        analytic_cost_s(kernel, problem, c), plan_sig(c)))
    keep = ranked[:max(1, max_candidates)]
    default = defaults_for(kernel, problem)
    if default in feas and default not in keep:
        keep.append(default)
    if not keep:        # every candidate over-commits VMEM: measure the
        keep = [default]   # default anyway (ops-level fallback shrinks)
    return keep, len(cands), len(feas)


def tune(kernel: str, problem: Problem, *,
         cache: Optional[PlanCache] = None,
         reps: int = 5, warmup: int = 1, max_candidates: int = 4,
         tie_rel: float = 0.05, force: bool = False,
         interpret: Optional[bool] = None,
         trace: Optional[TraceRecorder] = None) -> TuneResult:
    """Tune one (kernel, problem), consulting/updating the plan cache.

    A warm cache short-circuits before any jax work: ``measured == 0``
    and no spans are added to ``trace``.  ``force=True`` re-measures
    and overwrites the cached plan.
    """
    if cache is None:
        from repro.tuning.runtime import active_cache
        cache = active_cache()
    key = cache_key(kernel, problem)
    if not force:
        cached = cache.get(key)
        if cached is not None:
            return TuneResult(kernel, problem, cached, "cache", key,
                              measured=0, candidates=0, feasible=0,
                              pruned_to=0)

    keep, n_cands, n_feas = shortlist(kernel, problem, max_candidates)
    results: List[Tuple[Plan, JitterStats]] = []
    for plan in keep:
        fn = make_runner(kernel, problem, plan, interpret=interpret)
        stats = measure_callable(
            fn, reps=reps, warmup=warmup, trace=trace,
            label=f"{kernel}/{problem.sig}/{plan_sig(plan)}")
        results.append((plan, stats))
    best_plan, best_stats = select_plan(results, tie_rel=tie_rel)

    cache.put(key, best_plan,
              kernel=kernel, shape=problem.sig, dtype=problem.dtype,
              objective=best_stats.as_dict(),
              candidates=n_cands, feasible=n_feas,
              measured_plans=len(results), reps=reps)
    cache.save()
    return TuneResult(kernel, problem, dict(best_plan), "measured",
                      key, measured=len(results) * max(1, reps),
                      candidates=n_cands, feasible=n_feas,
                      pruned_to=len(results), stats=best_stats)
