"""Persistent JSON plan cache.

Tuning is offline (paper §4.3: decisions are made ahead of execution),
so winning plans persist to disk and subsequent runs — the CLI, the
benchmarks, and the kernel wrappers themselves — hit the cache with
zero measurements.

Keying: ``kernel|problem.sig|env`` where ``env`` is a digest of the
environment fields of ``repro.obs.report.hw_fingerprint()`` plus the
JAX backend.  A plan tuned on one machine/backend/JAX version is never
silently reused on another (the problem ``sig`` already carries shape
and dtype).  Model-level serving plans (tuning.model) live in the same
store under the ``model|...`` namespace.

Schema v2 (the ``model|`` namespace PR) only widened the key space;
entry shape is unchanged, so v1 files written by older tuners load
without warnings (``_ACCEPTED_SCHEMA_VERSIONS``) — a cache is never
invalidated by upgrading the tuner.

The cache degrades, never fails: an unreadable or mis-shaped file (or
entry) warns once and behaves as empty, so a corrupt cache can only
cost re-tuning — it can never take the kernels down.  Transient read
errors (OSError family) are retried with jittered backoff via
``resilience.retry_transient`` before the degradation kicks in, so an
NFS blip doesn't silently discard every tuned plan.

Location: ``$REPRO_PLAN_CACHE`` if set, else
``~/.cache/repro/tuning_plans.json``.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import tempfile
import time
import warnings
from typing import Any, Dict, Optional

CACHE_SCHEMA_VERSION = 2
# older schemas this reader still accepts (entry shape is identical)
_ACCEPTED_SCHEMA_VERSIONS = (1, CACHE_SCHEMA_VERSION)
CACHE_PATH_ENV = "REPRO_PLAN_CACHE"
DEFAULT_CACHE_PATH = "~/.cache/repro/tuning_plans.json"

# hw_fingerprint fields that identify the execution environment for
# plan reuse (the paper-config digest is model-level, not kernel-level)
_ENV_KEYS = ("python", "platform", "machine", "jax", "numpy", "backend")


def env_fingerprint() -> Dict[str, Any]:
    """The plan-relevant slice of ``obs.report.hw_fingerprint()``."""
    from repro.obs.report import hw_fingerprint
    fp = hw_fingerprint()
    return {k: fp.get(k) for k in _ENV_KEYS}


def env_sig(fp: Optional[Dict[str, Any]] = None) -> str:
    fp = env_fingerprint() if fp is None else fp
    blob = json.dumps({k: fp.get(k) for k in _ENV_KEYS}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def cache_key(kernel: str, problem) -> str:
    return f"{kernel}|{problem.sig}|{env_sig()}"


def _valid_entry(entry: Any) -> bool:
    return (isinstance(entry, dict)
            and isinstance(entry.get("plan"), dict)
            and all(isinstance(k, str) and isinstance(v, int)
                    and not isinstance(v, bool)
                    for k, v in entry["plan"].items()))


class PlanCache:
    """Load-once, save-atomically plan store with hit/miss counters."""

    def __init__(self, path: Optional[str] = None):
        raw = path or os.environ.get(CACHE_PATH_ENV) \
            or DEFAULT_CACHE_PATH
        self.path = pathlib.Path(raw).expanduser()
        self.hits = 0
        self.misses = 0
        # chaos seam: called as hook("read_cache", path) before the
        # read; a TransientIOFault here is absorbed by retry_transient
        self.fault_hook = None
        self._plans: Optional[Dict[str, Dict[str, Any]]] = None

    # ------------------------------------------------------------- load

    def _read_text(self) -> str:
        from repro.resilience.retry import retry_transient

        def attempt():
            if self.fault_hook is not None:
                self.fault_hook("read_cache", self.path)
            return self.path.read_text(encoding="utf-8")

        return retry_transient(attempt, attempts=3, base_delay=0.005)

    def _load(self) -> Dict[str, Dict[str, Any]]:
        if self._plans is not None:
            return self._plans
        self._plans = {}
        if self.path.exists():
            try:
                doc = json.loads(self._read_text())
                if (not isinstance(doc, dict)
                        or doc.get("schema_version")
                        not in _ACCEPTED_SCHEMA_VERSIONS
                        or not isinstance(doc.get("plans"), dict)):
                    raise ValueError("unrecognized plan-cache schema")
                self._plans = dict(doc["plans"])
            except (ValueError, OSError, RuntimeError) as e:
                # RuntimeError: resilience.RetriesExhausted — the
                # transient-I/O retries gave up; still degrade, never
                # take the kernels down
                warnings.warn(
                    f"plan cache {self.path} unreadable ({e}); "
                    "ignoring it and falling back to default plans",
                    RuntimeWarning, stacklevel=3)
        return self._plans

    # ----------------------------------------------------------- access

    def get(self, key: str) -> Optional[Dict[str, int]]:
        """The cached plan for ``key``, or None.  Mis-shaped entries
        warn and count as misses."""
        entry = self._load().get(key)
        if entry is not None and not _valid_entry(entry):
            warnings.warn(
                f"plan cache {self.path}: entry {key!r} is mis-shaped; "
                "ignoring it", RuntimeWarning, stacklevel=3)
            entry = None
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        return dict(entry["plan"])

    def entry(self, key: str) -> Optional[Dict[str, Any]]:
        """Full cache record (plan + provenance), if valid."""
        entry = self._load().get(key)
        return dict(entry) if _valid_entry(entry) else None

    def put(self, key: str, plan: Dict[str, int],
            **meta: Any) -> None:
        self._load()[key] = {
            "plan": {k: int(v) for k, v in plan.items()},
            "tuned_at": time.time(),
            "env": env_fingerprint(),
            **meta,
        }

    def __len__(self) -> int:
        return len(self._load())

    # ------------------------------------------------------------- save

    def save(self) -> pathlib.Path:
        """Atomic write (tmp + rename): a crashed tuner never leaves a
        half-written cache behind."""
        doc = {"schema_version": CACHE_SCHEMA_VERSION,
               "plans": self._load()}
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name + ".")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return self.path
