"""Tuning problems: the shape/dtype signatures plans are keyed by.

A *problem* is the static description of one kernel invocation — every
field that changes the optimal block plan (shapes, dtype, masking
flags) and nothing that doesn't (the actual array values).  Problems
are frozen dataclasses so they hash, compare, and serialize into the
plan-cache key deterministically; ``sig`` is the canonical short form
used in cache keys and log lines.

A *plan* is just a ``{param_name: int}`` dict (``bm/bn/bk`` for
spm_matmul, ``bq/bk`` for flash_attention, ``chunk`` for wkv6) — the
kwargs the public kernel wrappers accept.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Union


@dataclass(frozen=True)
class MatmulProblem:
    """C[m,n] = A[m,k] @ B[k,n]."""
    m: int
    k: int
    n: int
    dtype: str = "float32"

    @property
    def sig(self) -> str:
        return f"{self.m}x{self.k}x{self.n}-{self.dtype}"


@dataclass(frozen=True)
class AttentionProblem:
    """q: [B,Sq,H,D]; k,v: [B,Sk,KV,D] (GQA group = H // KV)."""
    batch: int
    seq_q: int
    seq_k: int
    heads: int
    kv_heads: int
    head_dim: int
    causal: bool = True
    window: int = 0
    dtype: str = "float32"

    @property
    def sig(self) -> str:
        tag = "causal" if self.causal else "full"
        if self.window:
            tag += f"-w{self.window}"
        return (f"{self.batch}x{self.seq_q}x{self.seq_k}"
                f"h{self.heads}kv{self.kv_heads}d{self.head_dim}"
                f"-{tag}-{self.dtype}")


@dataclass(frozen=True)
class WkvProblem:
    """r,k,v,w_log: [B,S,H,K]; u: [H,K]."""
    batch: int
    seq: int
    heads: int
    key_dim: int
    dtype: str = "float32"

    @property
    def sig(self) -> str:
        return (f"{self.batch}x{self.seq}x{self.heads}x{self.key_dim}"
                f"-{self.dtype}")


Problem = Union[MatmulProblem, AttentionProblem, WkvProblem]
Plan = Dict[str, int]


def plan_sig(plan: Plan) -> str:
    """Canonical short form of a plan, e.g. ``bk0.bm256.bn512``."""
    return ".".join(f"{k}{v}" for k, v in sorted(plan.items()))


def parse_problem(kernel: str, text: str,
                  dtype: str = "float32") -> Problem:
    """CLI shape syntax -> problem (``x``/``,``-separated ints):

    - spm_matmul:       M x K x N
    - flash_attention:  B x S x H x KV x D   (Sq = Sk = S, causal)
    - wkv6:             B x S x H x K
    """
    dims: List[int] = [int(p) for p in
                       text.replace(",", "x").split("x") if p]
    if kernel == "spm_matmul":
        if len(dims) != 3:
            raise ValueError(f"spm_matmul wants MxKxN, got {text!r}")
        return MatmulProblem(*dims, dtype=dtype)
    if kernel == "flash_attention":
        if len(dims) != 5:
            raise ValueError(
                f"flash_attention wants BxSxHxKVxD, got {text!r}")
        b, s, h, kv, d = dims
        return AttentionProblem(b, s, s, h, kv, d, dtype=dtype)
    if kernel == "wkv6":
        if len(dims) != 4:
            raise ValueError(f"wkv6 wants BxSxHxK, got {text!r}")
        return WkvProblem(*dims, dtype=dtype)
    raise KeyError(f"unknown kernel {kernel!r}")


# The shapes benchmarks/bench_kernels.py times — scripts/tune.py tunes
# these by default so a tuning run warms exactly the plans the bench
# trajectory reports on.
DEFAULT_PROBLEMS: Dict[str, Problem] = {
    "spm_matmul": MatmulProblem(512, 512, 512),
    "flash_attention": AttentionProblem(1, 256, 256, 4, 2, 64),
    "wkv6": WkvProblem(1, 256, 2, 64),
}
