"""End-to-end measurement of model serving plans.

Same pipeline as the kernel autotuner one level up: enumerate ->
prune (VMEM + roofline, tuning.model) -> measure -> persist.  The
measured unit is a *full serve pass* — one prefill plus ``gen``
AOT-compiled decode steps with a donated KV cache — timed by the same
GC-quiesced ``measure_callable`` the kernel tuner uses, so a warm
cache still means zero measurement spans on the trace.

Compilation is hoisted out of the timed region entirely: the runner
builds params once, AOT-compiles prefill and the decode step
(``compat.aot_compile``), and the thunk only executes the compiled
programs.  That is what lets p99/CoV of the pass speak for the plan
rather than for compile jitter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from repro.obs import JitterStats, TraceRecorder
from repro.tuning.measure import measure_callable, select_plan
from repro.tuning.model import (ModelProblem, default_model_plan,
                                enumerate_model_candidates,
                                model_analytic_cost_s, model_cache_key,
                                model_feasible, problem_config)
from repro.tuning.plan import Plan, plan_sig
from repro.tuning.plan_cache import PlanCache


@dataclass(frozen=True)
class ModelTuneResult:
    problem: ModelProblem
    plan: Plan
    source: str                       # "cache" | "measured"
    key: str
    measured: int                     # timed passes performed (0 = warm)
    candidates: int
    feasible: int
    pruned_to: int
    stats: Optional[JitterStats] = None          # winning plan, full pass
    default_plan: Optional[Plan] = None
    default_stats: Optional[JitterStats] = None  # always measured cold


def us_per_token(stats: JitterStats, problem: ModelProblem) -> float:
    """Median full-pass latency amortized over the generated tokens."""
    return stats.median / max(1, problem.gen)


def make_serve_runner(cfg, problem: ModelProblem,
                      plan: Plan) -> Callable[[], None]:
    """A zero-arg thunk executing one full serve pass (prefill +
    ``gen`` decode steps) under ``plan``, with all compilation done
    before the thunk is returned."""
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.models import lm as lm_mod
    from repro.models.lm import RunOptions

    B, P, G = problem.batch, problem.prompt_len, problem.gen
    opts = RunOptions(chunk_q=int(plan["chunk_q"]),
                      chunk_kv=int(plan["chunk_kv"]),
                      cache_len=P + G, remat=False,
                      decode_scan=bool(plan["decode_scan"]))

    key = jax.random.PRNGKey(B + P + G)
    params = lm_mod.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, P, cfg.d_model))

    prefill_j = jax.jit(lambda p, b: lm_mod.prefill(cfg, p, b, opts))
    step_j = compat.donated_jit(
        lambda p, c, t, i: lm_mod.decode_step(cfg, p, c, t, i, opts),
        donate_argnums=(1,))
    prefill_c = compat.aot_compile(prefill_j, params, batch)
    logits0, cache0 = prefill_c(params, batch)
    tok0 = jnp.argmax(logits0[:, :cfg.vocab_size], axis=-1)
    step_c = compat.aot_compile(step_j, params, cache0, tok0,
                                jnp.int32(P))
    del logits0, cache0, tok0

    def run() -> None:
        logits, cache = prefill_c(params, batch)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        for i in range(G):
            logits, cache = step_c(params, cache, tok, jnp.int32(P + i))
            tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        jax.block_until_ready(tok)

    return run


def model_shortlist(cfg, problem: ModelProblem,
                    max_candidates: int = 4) \
        -> Tuple[List[Plan], int, int]:
    """Enumerate, VMEM-filter, rank by the analytic serve-pass bound;
    the default plan is always measured (it is the tuned-vs-default
    baseline, not just a fallback)."""
    cands = enumerate_model_candidates(cfg, problem)
    feas = [c for c in cands if model_feasible(cfg, problem, c)]
    ranked = sorted(feas, key=lambda c: (
        model_analytic_cost_s(cfg, problem, c), plan_sig(c)))
    keep = ranked[:max(1, max_candidates)]
    default = default_model_plan(cfg, problem)
    if default not in keep:
        keep.append(default)
    return keep, len(cands), len(feas)


def tune_model(problem: ModelProblem, *,
               cache: Optional[PlanCache] = None,
               reps: int = 5, warmup: int = 1, max_candidates: int = 4,
               tie_rel: float = 0.05, force: bool = False,
               trace: Optional[TraceRecorder] = None) -> ModelTuneResult:
    """Tune one serving problem end-to-end, consulting/updating the
    shared plan cache under the ``model|`` namespace.

    A warm cache short-circuits before any jax work (``measured == 0``,
    no spans on ``trace``).  On a cold run the result carries both the
    winner's stats and the default plan's, so callers can print the
    tuned-vs-default step comparison without re-measuring.
    """
    if cache is None:
        from repro.tuning.runtime import active_cache
        cache = active_cache()
    key = model_cache_key(problem)
    if not force:
        cached = cache.get(key)
        if cached is not None:
            return ModelTuneResult(problem, cached, "cache", key,
                                   measured=0, candidates=0, feasible=0,
                                   pruned_to=0)

    cfg = problem_config(problem)
    keep, n_cands, n_feas = model_shortlist(cfg, problem, max_candidates)
    default = default_model_plan(cfg, problem)
    results: List[Tuple[Plan, JitterStats]] = []
    for plan in keep:
        fn = make_serve_runner(cfg, problem, plan)
        stats = measure_callable(
            fn, reps=reps, warmup=warmup, trace=trace,
            label=f"model/{problem.sig}/{plan_sig(plan)}")
        results.append((plan, stats))
    best_plan, best_stats = select_plan(results, tie_rel=tie_rel)
    default_stats = next(s for p, s in results if p == default)

    cache.put(key, best_plan,
              kernel="model", shape=problem.sig, dtype=problem.dtype,
              objective=best_stats.as_dict(),
              default_objective=default_stats.as_dict(),
              candidates=n_cands, feasible=n_feas,
              measured_plans=len(results), reps=reps)
    cache.save()
    return ModelTuneResult(problem, dict(best_plan), "measured", key,
                           measured=len(results) * max(1, reps),
                           candidates=n_cands, feasible=n_feas,
                           pruned_to=len(results), stats=best_stats,
                           default_plan=dict(default),
                           default_stats=default_stats)
