"""Jitter-aware offline kernel autotuner with a persistent plan cache.

The paper's schedule construction (§4.3) decides feasibility and
placement *ahead of execution*; this package applies the same
discipline to Pallas block plans: candidates are enumerated and pruned
analytically (VMEM feasibility + roofline ranking), survivors are
measured under the predictability observatory, and selection is by
**p99 latency with a CoV tie-break** — never by mean alone — so a
faster plan is never accepted at the cost of execution-time
fluctuation.  Winners persist to a JSON cache keyed by
(kernel, shape/dtype, environment fingerprint); warm runs perform
zero measurements.

Layers:

- ``plan``        — problems (shape/dtype signatures) and plan dicts.
- ``candidates``  — per-kernel enumeration + shape-safe defaults.
- ``cost_model``  — VMEM feasibility (the SPM-capacity rule) and the
  analytic roofline pruner.
- ``measure``     — TraceRecorder-backed timing + the jitter-aware
  selection objective.
- ``plan_cache``  — the persistent store ($REPRO_PLAN_CACHE).
- ``autotuner``   — ``tune()``: enumerate -> prune -> measure -> persist.
- ``runtime``     — ``resolve_plan()``: what the kernel wrappers call
  (explicit args > cached plan > defaults; $REPRO_AUTOTUNE=0 disables
  the cache consult).
- ``model``/``model_tuner`` — the same pipeline one level up: serving
  plans (prefill chunking, decode scan-vs-unroll, decode weight-pass
  tile pins) measured as full prefill+decode passes and cached under
  the ``model|`` key namespace; ``resolve_model_plan()`` is what the
  serving launcher calls.

CLI: ``scripts/tune.py`` (``--model`` for serving plans).
Regression gate: ``scripts/bench_diff.py``.
"""
from repro.tuning.autotuner import TuneResult, make_runner, shortlist, tune
from repro.tuning.candidates import (TUNE_SPECS, defaults_for,
                                     enumerate_candidates)
from repro.tuning.cost_model import (analytic_cost_s, cost_summary,
                                     feasibility, vmem_need)
from repro.tuning.measure import (MEASURE_TRACK, measure_callable,
                                  measurement_count, select_plan)
from repro.tuning.plan import (DEFAULT_PROBLEMS, AttentionProblem,
                               MatmulProblem, Plan, Problem, WkvProblem,
                               parse_problem, plan_sig)
from repro.tuning.model import (MODEL_NS, ModelProblem,
                                default_model_plan,
                                enumerate_model_candidates,
                                model_analytic_cost_s, model_cache_key,
                                model_feasible, parse_model_problem,
                                problem_config, resolve_model_plan)
from repro.tuning.model_tuner import (ModelTuneResult, make_serve_runner,
                                      model_shortlist, tune_model,
                                      us_per_token)
from repro.tuning.plan_cache import (PlanCache, cache_key,
                                     env_fingerprint, env_sig)
from repro.tuning.runtime import (active_cache, autotune_enabled, reset,
                                  resolve_plan)

__all__ = [
    "AttentionProblem",
    "DEFAULT_PROBLEMS",
    "MEASURE_TRACK",
    "MatmulProblem",
    "Plan",
    "PlanCache",
    "Problem",
    "TUNE_SPECS",
    "TuneResult",
    "WkvProblem",
    "active_cache",
    "analytic_cost_s",
    "autotune_enabled",
    "cache_key",
    "cost_summary",
    "defaults_for",
    "enumerate_candidates",
    "env_fingerprint",
    "env_sig",
    "feasibility",
    "make_runner",
    "measure_callable",
    "measurement_count",
    "parse_problem",
    "plan_sig",
    "reset",
    "resolve_plan",
    "select_plan",
    "shortlist",
    "tune",
    "vmem_need",
]
