"""Call-time plan resolution for the kernel wrappers.

The public kernel entry points (``kernels/*/ops.py``) take their block
parameters as ``None`` defaults and resolve the actual plan here.
Precedence, highest first:

1. explicit arguments — a caller who passes ``bm=128`` always wins,
2. a cached tuned plan for this (kernel, problem, environment),
3. the shape-safe built-in defaults (candidates.defaults_for).

The cache consult is a dict lookup after the first call (one shared
``PlanCache`` per process, loaded lazily) and can be disabled entirely
with ``REPRO_AUTOTUNE=0`` — tests run with it off so tier-1 never
reads a developer's cache; scripts/tune.py and the benchmarks pass
caches explicitly.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

from repro.tuning.candidates import defaults_for
from repro.tuning.plan import Plan, Problem
from repro.tuning.plan_cache import PlanCache, cache_key

AUTOTUNE_ENV = "REPRO_AUTOTUNE"

_active_cache: Optional[PlanCache] = None


def autotune_enabled() -> bool:
    return os.environ.get(AUTOTUNE_ENV, "1").lower() \
        not in ("0", "off", "false", "no")


def active_cache() -> PlanCache:
    """The process-wide plan cache (path from $REPRO_PLAN_CACHE)."""
    global _active_cache
    if _active_cache is None:
        _active_cache = PlanCache()
    return _active_cache


def reset(cache: Optional[PlanCache] = None) -> None:
    """Swap/clear the process cache (tests: after changing env vars)."""
    global _active_cache
    _active_cache = cache


def resolve_plan(kernel: str, problem: Problem,
                 overrides: Dict[str, Optional[int]]) -> Plan:
    """Merge defaults <- cached plan <- explicit (non-None) args."""
    plan = defaults_for(kernel, problem)
    explicit = {k: v for k, v in overrides.items() if v is not None}
    if len(explicit) < len(overrides) and autotune_enabled():
        cached = active_cache().get(cache_key(kernel, problem))
        if cached is not None:
            plan.update({k: v for k, v in cached.items()
                         if k in plan})
    plan.update(explicit)
    return plan
