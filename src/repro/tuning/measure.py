"""Measurement + jitter-aware plan selection.

Like the paper's evaluation protocol (§5.1), candidates are judged on
their *distribution* of execution times, not a single number: each
surviving plan runs ``reps`` times under an ``obs.TraceRecorder``
(one span per measured rep on the ``autotune`` track — the span count
IS the measurement count, which is how tests and the CLI verify a
warm cache performs zero measurements), and selection goes to the
lowest **p99** latency with a **CoV tie-break**: any plan whose p99 is
within ``tie_rel`` of the best competes, and the steadiest (lowest
coefficient of variation) of those wins.  Speed never comes at the
cost of predictability.
"""
from __future__ import annotations

import gc
import time
from typing import Callable, List, Optional, Sequence, Tuple

from repro.obs import JitterStats, TraceRecorder, jitter_stats
from repro.tuning.plan import Plan, plan_sig

MEASURE_TRACK = "autotune"


def measure_callable(fn: Callable[[], None], *, reps: int = 5,
                     warmup: int = 1,
                     trace: Optional[TraceRecorder] = None,
                     label: str = "plan") -> JitterStats:
    """Wall-clock ``fn()`` ``reps`` times (after ``warmup`` untimed
    runs that absorb compilation) and summarize as JitterStats (us)."""
    for _ in range(max(0, warmup)):
        fn()
    samples: List[float] = []
    # GC pauses are the dominant interference source on the CPU
    # measurement path — collect up front, then keep the collector out
    # of the timed region (the paper's no-interference protocol).
    gc.collect()
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for i in range(max(1, reps)):
            t0 = time.perf_counter()
            fn()
            t1 = time.perf_counter()
            samples.append((t1 - t0) * 1e6)
            if trace is not None:
                trace.add_span(label, MEASURE_TRACK, t0 * 1e6, t1 * 1e6,
                               cat="measure", rep=i)
    finally:
        if gc_was_enabled:
            gc.enable()
    return jitter_stats(samples)


def measurement_count(trace: TraceRecorder) -> int:
    """Number of measured reps recorded on ``trace``."""
    return len(trace.spans_on(MEASURE_TRACK))


def select_plan(results: Sequence[Tuple[Plan, JitterStats]],
                tie_rel: float = 0.05) -> Tuple[Plan, JitterStats]:
    """Jitter-aware argmin: best p99; plans within ``tie_rel`` of it
    are tied and the lowest-CoV one wins."""
    if not results:
        raise ValueError("select_plan needs at least one measurement")
    best_p99 = min(s.p99 for _, s in results)
    pool = [(p, s) for p, s in results
            if s.p99 <= best_p99 * (1.0 + tie_rel)]
    return min(pool, key=lambda ps: (ps[1].cov, ps[1].p99,
                                     plan_sig(ps[0])))
