"""Model-level serving plans: the autotuner one level up the stack.

PR 8 tuned *kernel* block plans; the serving path still ran hand-picked
``RunOptions`` chunk sizes and derived its WCET banner from default
tile constants.  This module closes that gap with the same offline
discipline applied end-to-end:

- a ``ModelProblem`` is the static description of one serving
  configuration — architecture, batch, prompt/generation lengths, the
  reduced dims the launcher actually builds, dtype — everything that
  changes the optimal plan and nothing that doesn't;
- a model *plan* is a flat ``{name: int}`` dict (same shape as kernel
  plans, so the persistent cache validates it unchanged):

  ``chunk_q`` / ``chunk_kv``   prefill attention chunking (RunOptions),
  ``decode_scan``              0/1: unroll vs scan the decode layer loop,
  ``mm_bm`` / ``mm_bn``        the decode weight-pass matmul tile pins —
                               resolved through the KERNEL plan cache
                               (spm_matmul namespace), recorded in the
                               model plan, and fed to
                               ``core.tpu_mapping.serve_step_schedule``
                               so the WCET bound tracks the served plan.

Candidates are enumerated small, pruned by the same VMEM-feasibility
and roofline machinery the kernel tuner uses (the prefill attention
working set is priced as a flash_attention problem; the decode step as
a weight-pass roofline), and the survivors are measured end-to-end by
``tuning.model_tuner``.  Winners persist in the shared
``$REPRO_PLAN_CACHE`` under the ``model|`` key namespace.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.analysis.roofline import kernel_bound_s
from repro.core.tpu_mapping import V5E, TPUChip
from repro.tuning.candidates import _tile_candidates
from repro.tuning.cost_model import analytic_cost_s as _kernel_cost_s
from repro.tuning.cost_model import feasibility as _kernel_feasibility
from repro.tuning.plan import AttentionProblem, MatmulProblem, Plan
from repro.tuning.plan_cache import cache_key

# Cache namespace: model plans share the kernel cache file but never a
# key (``model|<problem.sig>|<env>``).
MODEL_NS = "model"

_CHUNK_TILES = (16, 32, 64, 128, 256, 512)


@dataclass(frozen=True)
class ModelProblem:
    """One serving configuration, as the launcher builds it.

    ``layers``/``d_model``/``vocab`` are the reduced dims
    (configs.reduce_config); 0 means --full (the registered size).
    """
    arch: str
    batch: int
    prompt_len: int
    gen: int
    layers: int = 2
    d_model: int = 128
    vocab: int = 512
    dtype: str = "float32"

    @property
    def sig(self) -> str:
        dims = ("full" if not self.layers
                else f"l{self.layers}d{self.d_model}v{self.vocab}")
        return (f"{self.arch}-b{self.batch}p{self.prompt_len}"
                f"g{self.gen}-{dims}-{self.dtype}")


def model_cache_key(problem: ModelProblem) -> str:
    return cache_key(MODEL_NS, problem)


def problem_config(problem: ModelProblem):
    """The ModelConfig this problem describes (reduced unless full)."""
    from repro.configs import get_config, reduce_config
    cfg = get_config(problem.arch)
    if problem.layers:
        cfg = reduce_config(cfg, layers=problem.layers,
                            d_model=problem.d_model,
                            vocab=problem.vocab)
    return cfg


def parse_model_problem(arch: str, text: str, *, layers: int = 2,
                        d_model: int = 128, vocab: int = 512,
                        dtype: str = "float32") -> ModelProblem:
    """CLI shape syntax ``BxPxG`` (batch x prompt_len x gen)."""
    dims = [int(p) for p in text.replace(",", "x").split("x") if p]
    if len(dims) != 3:
        raise ValueError(f"model shape wants BxPxG, got {text!r}")
    b, p, g = dims
    return ModelProblem(arch, b, p, g, layers=layers, d_model=d_model,
                        vocab=vocab, dtype=dtype)


# ------------------------------------------------------- kernel pins

def decode_matmul_problem(cfg, problem: ModelProblem) -> MatmulProblem:
    """The decode step's aggregate weight pass as a matmul problem:
    [B, d_model] activations against every weight matrix once."""
    from repro.models.lm import param_count
    n_params = param_count(cfg)
    n_eff = max(cfg.d_model, 2 * n_params // cfg.d_model)
    return MatmulProblem(problem.batch, cfg.d_model, n_eff,
                         dtype=problem.dtype)


def kernel_pins(cfg, problem: ModelProblem) -> Dict[str, int]:
    """Resolve the decode weight-pass tile plan through the KERNEL
    namespace of the plan cache (tuned spm_matmul plan if present,
    shape-safe defaults otherwise) and flatten it into the model-plan
    pin fields.  These pins parameterize the WCET schedule
    (core.tpu_mapping.serve_step_schedule) — recording them in the
    model plan is what lets a test prove the serve banner derives from
    the plan actually served."""
    from repro.tuning.runtime import resolve_plan
    mm = decode_matmul_problem(cfg, problem)
    plan = resolve_plan("spm_matmul", mm,
                        {"bm": None, "bn": None, "bk": None})
    return {"mm_bm": min(int(plan["bm"]), mm.m),
            "mm_bn": min(int(plan["bn"]), mm.n)}


# ------------------------------------------------ defaults/candidates

def default_model_plan(cfg, problem: ModelProblem) -> Plan:
    """The plan the serving path ran before tuning existed: 32-token
    prefill chunks, decode loop structure from cfg.scan_layers, tiles
    from the kernel-plan resolution."""
    plan = {"chunk_q": 32 if problem.prompt_len % 32 == 0
            else problem.prompt_len,
            "chunk_kv": 32 if problem.prompt_len % 32 == 0
            else problem.prompt_len,
            "decode_scan": int(bool(cfg.scan_layers))}
    plan.update(kernel_pins(cfg, problem))
    return plan


def enumerate_model_candidates(cfg, problem: ModelProblem) -> List[Plan]:
    """Small grid over the knobs that change the executed program;
    every candidate carries the same kernel pins."""
    pins = kernel_pins(cfg, problem)
    chunks = _tile_candidates(problem.prompt_len, _CHUNK_TILES)
    scans = [int(bool(cfg.scan_layers))]
    if cfg.num_layers and cfg.num_layers <= 8:
        # unrolling hundreds of layers would explode compile time; the
        # scan-vs-unroll trade is only worth measuring on short stacks
        scans = sorted({0, 1} | set(scans))
    cands = [{"chunk_q": cq, "chunk_kv": ckv, "decode_scan": sc, **pins}
             for cq in chunks for ckv in chunks for sc in scans]
    default = default_model_plan(cfg, problem)
    if default not in cands:
        cands.append(default)
    return cands


# ------------------------------------------------------ analytic prune

def _prefill_attn_problem(cfg, problem: ModelProblem) \
        -> Optional[AttentionProblem]:
    a = cfg.attention
    if a is None:
        return None
    return AttentionProblem(problem.batch, problem.prompt_len,
                            problem.prompt_len, a.num_heads,
                            a.num_kv_heads, a.head_dim,
                            dtype=problem.dtype)


def model_feasible(cfg, problem: ModelProblem, plan: Plan,
                   chip: TPUChip = V5E) -> bool:
    """VMEM feasibility of the prefill attention working set under the
    plan's chunking — the same scratchpad-capacity rule the kernel
    tuner applies, evaluated on the chunk the model plan pins."""
    ap = _prefill_attn_problem(cfg, problem)
    if ap is None:
        return True
    attn_plan = {"bq": min(plan["chunk_q"] or ap.seq_q, ap.seq_q),
                 "bk": min(plan["chunk_kv"] or ap.seq_k, ap.seq_k)}
    return _kernel_feasibility("flash_attention", ap, attn_plan,
                               chip).fits


def model_analytic_cost_s(cfg, problem: ModelProblem, plan: Plan,
                          chip: TPUChip = V5E) -> float:
    """Modeled worst-case seconds for one full serve pass (prefill +
    ``gen`` decode steps) — the pruning objective, never the verdict.

    Prefill attention is priced per layer with the kernel cost model
    under the plan's chunking; every decode step pays the weight-pass
    roofline (all parameters stream once per token).
    """
    cost = 0.0
    ap = _prefill_attn_problem(cfg, problem)
    if ap is not None:
        attn_plan = {"bq": min(plan["chunk_q"] or ap.seq_q, ap.seq_q),
                     "bk": min(plan["chunk_kv"] or ap.seq_k, ap.seq_k)}
        cost += cfg.num_layers * _kernel_cost_s(
            "flash_attention", ap, attn_plan, chip)
    mm = decode_matmul_problem(cfg, problem)
    elem = 2 if "16" in problem.dtype else 4
    step = kernel_bound_s(2.0 * mm.m * mm.k * mm.n,
                          float(mm.k) * mm.n * elem,
                          mxu_eff=chip.worst_mxu_eff,
                          hbm_derate=chip.worst_hbm_derate)
    return cost + problem.gen * step


# --------------------------------------------------------- resolution

def resolve_model_plan(cfg, problem: ModelProblem,
                       overrides: Optional[Dict[str, Optional[int]]]
                       = None) -> Dict[str, object]:
    """Serving-time plan resolution, same precedence as the kernel
    wrappers: explicit (non-None) overrides > cached tuned plan >
    defaults.  Returns ``{"plan": Plan, "source": str}`` so the serve
    banner can say where its plan came from.

    The cache consult goes through the shared process cache and is
    keyed on the environment fingerprint (backend included): a plan
    tuned on CPU never resolves on a TPU fingerprint.
    """
    from repro.tuning.runtime import active_cache, autotune_enabled
    plan = default_model_plan(cfg, problem)
    overrides = overrides or {}
    explicit = {k: int(v) for k, v in overrides.items()
                if v is not None and k in plan}
    source = "defaults"
    if len(explicit) < len(plan) and autotune_enabled():
        cached = active_cache().get(model_cache_key(problem))
        if cached is not None:
            plan.update({k: v for k, v in cached.items() if k in plan})
            source = "cache"
    if explicit:
        plan.update(explicit)
        source = "explicit" if len(explicit) == len(plan) \
            else f"explicit+{source}"
    return {"plan": plan, "source": source}
