"""Analytic pruning stage of the autotuner.

Before anything is measured, every candidate block plan is checked for
VMEM feasibility (the same scratchpad-capacity rule the paper's
scheduler applies before constructing a static schedule — an
infeasible plan is rejected *offline*, never discovered at runtime)
and ranked by a roofline bound (analysis.roofline.kernel_bound_s with
the worst-case derates from core.tpu_mapping.TPUChip) plus a small
per-grid-step dispatch term so plans that trade bandwidth for a much
longer sequential grid don't all rank identically.

The traffic models mirror the kernels' BlockSpec index maps — the
BlockSpec IS the static DMA schedule, so bytes-moved is computable
exactly from (problem, plan):

- spm_matmul: A is re-streamed once per B-column block, B once per
  row sweep (3D path), C written once.
- flash_attention: K/V are re-streamed once per query block
  (flash cost), Q/O move once.
- wkv6: the recurrent state never leaves VMEM; inputs/outputs stream
  once.  Compute grows with the chunk length (the [L,L,K] intra-chunk
  working set), so chunk choice is a real compute/overhead trade.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.analysis.roofline import kernel_bound_s
from repro.core.tpu_mapping import V5E, TPUChip
from repro.tuning.plan import (AttentionProblem, MatmulProblem, Plan,
                               Problem, WkvProblem)

F32 = 4

# Per-grid-step dispatch/pipeline overhead (seconds) for ranking only:
# real parts pay a small fixed cost per grid step, and the interpret
# measurement path pays a much larger one — either way, fewer steps at
# equal traffic should outrank more steps.
GRID_STEP_OVERHEAD_S = 2e-7


def _elem_bytes(dtype: str) -> int:
    return {"bfloat16": 2, "float16": 2, "float32": 4,
            "float64": 8}.get(dtype, 4)


@dataclass(frozen=True)
class Feasibility:
    fits: bool
    vmem_need: int
    vmem_bytes: int


def _clamped_matmul(p: MatmulProblem, plan: Plan) -> Tuple[int, int, int]:
    """The kernel clamps bm/bn to the problem dims; mirror that so the
    model prices what actually runs."""
    bm = min(plan["bm"], p.m)
    bn = min(plan["bn"], p.n)
    bk = plan.get("bk", 0)
    bk = p.k if bk <= 0 or bk >= p.k else bk
    return bm, bn, bk


def vmem_need(kernel: str, problem: Problem, plan: Plan) -> int:
    """Bytes of VMEM the plan pins, double-buffering streamed tiles —
    the TPU spelling of the paper's SPM residency requirement."""
    e = _elem_bytes(problem.dtype)
    if kernel == "spm_matmul":
        bm, bn, bk = _clamped_matmul(problem, plan)
        # A tile + B block + C tile, double-buffered A/C (ops.vmem_plan
        # applies the identical rule at call time).
        return (2 * bm * bk + bk * bn + 2 * bm * bn) * e
    if kernel == "flash_attention":
        p: AttentionProblem = problem
        bq = min(plan["bq"], p.seq_q)
        bk = min(plan["bk"], p.seq_k)
        d = p.head_dim
        # Q/O tiles + double-buffered K/V tiles + fp32 (m, l, acc)
        # scratch carried across the kv grid axis.
        return (2 * bq * d + 2 * 2 * bk * d) * e \
            + (bq * d + 2 * bq) * F32
    if kernel == "wkv6":
        w: WkvProblem = problem
        L = min(plan["chunk"], w.seq)
        K = w.key_dim
        # 4 streamed [L,K] inputs (double-buffered) + y tile + the
        # [L,L,K] intra-chunk decay working set (seg + P) + S scratch.
        return (2 * 4 * L * K + 2 * L * K) * _elem_bytes(w.dtype) \
            + (2 * L * L * K + L * L + K * K) * F32
    raise KeyError(f"unknown kernel {kernel!r}")


def feasibility(kernel: str, problem: Problem, plan: Plan,
                chip: TPUChip = V5E) -> Feasibility:
    need = vmem_need(kernel, problem, plan)
    return Feasibility(need <= chip.vmem_bytes, need, chip.vmem_bytes)


def grid_steps(kernel: str, problem: Problem, plan: Plan) -> int:
    """Sequential grid length — the number of pipeline steps the
    static schedule executes."""
    if kernel == "spm_matmul":
        p: MatmulProblem = problem
        bm, bn, bk = _clamped_matmul(p, plan)
        steps = (p.n // bn) * (p.m // bm)
        if bk < p.k:
            steps *= p.k // bk
        return steps
    if kernel == "flash_attention":
        a: AttentionProblem = problem
        bq = min(plan["bq"], a.seq_q)
        bk = min(plan["bk"], a.seq_k)
        return a.batch * a.heads * (a.seq_q // bq) * (a.seq_k // bk)
    if kernel == "wkv6":
        w: WkvProblem = problem
        L = min(plan["chunk"], w.seq)
        return w.batch * w.heads * (w.seq // L)
    raise KeyError(f"unknown kernel {kernel!r}")


def flops_bytes(kernel: str, problem: Problem,
                plan: Plan) -> Tuple[float, float]:
    """(flops, HBM bytes moved) for one invocation under ``plan``."""
    if kernel == "spm_matmul":
        p: MatmulProblem = problem
        e = _elem_bytes(p.dtype)
        bm, bn, bk = _clamped_matmul(p, plan)
        a_bytes = (p.n // bn) * p.m * p.k * e       # re-read per j
        if bk < p.k:                                # 3D accumulate path
            b_bytes = (p.m // bm) * p.k * p.n * e   # re-read per i
        else:
            b_bytes = p.k * p.n * e                 # resident per j
        c_bytes = p.m * p.n * e
        return 2.0 * p.m * p.k * p.n, a_bytes + b_bytes + c_bytes
    if kernel == "flash_attention":
        a: AttentionProblem = problem
        e = _elem_bytes(a.dtype)
        bq = min(plan["bq"], a.seq_q)
        q_bytes = 2 * a.batch * a.seq_q * a.heads * a.head_dim * e
        kv_bytes = (2 * a.batch * a.kv_heads * a.seq_k * a.head_dim
                    * e * (a.heads // a.kv_heads) * (a.seq_q // bq))
        flops = 4.0 * a.batch * a.heads * a.seq_q * a.seq_k * a.head_dim
        if a.causal:
            flops /= 2
        return flops, q_bytes + kv_bytes
    if kernel == "wkv6":
        w: WkvProblem = problem
        e = _elem_bytes(w.dtype)
        L = min(plan["chunk"], w.seq)
        nc = w.seq // L
        K = w.key_dim
        # per chunk: intra-chunk decay+scores (~3 L^2 K), A@v (2 L^2 K)
        # and the two state matmuls (~4 L K^2)
        flops = w.batch * w.heads * nc * (5.0 * L * L * K
                                          + 4.0 * L * K * K)
        io_bytes = 5 * w.batch * w.seq * w.heads * K * e \
            + w.batch * w.heads * K * K * F32
        return flops, io_bytes
    raise KeyError(f"unknown kernel {kernel!r}")


def analytic_cost_s(kernel: str, problem: Problem, plan: Plan,
                    chip: TPUChip = V5E) -> float:
    """Modeled worst-case seconds — the pruning objective.  Measurement
    (measure.py) decides among the survivors; this only has to rank."""
    flops, byts = flops_bytes(kernel, problem, plan)
    bound = kernel_bound_s(flops, byts,
                           mxu_eff=chip.worst_mxu_eff,
                           hbm_derate=chip.worst_hbm_derate)
    return bound + grid_steps(kernel, problem, plan) * GRID_STEP_OVERHEAD_S


def cost_summary(kernel: str, problem: Problem, plan: Plan,
                 chip: TPUChip = V5E) -> Dict[str, float]:
    """Itemized model output (CLI/report explainability)."""
    flops, byts = flops_bytes(kernel, problem, plan)
    feas = feasibility(kernel, problem, plan, chip)
    return {
        "flops": flops,
        "bytes": byts,
        "grid_steps": float(grid_steps(kernel, problem, plan)),
        "vmem_need": float(feas.vmem_need),
        "fits": float(feas.fits),
        "cost_s": analytic_cost_s(kernel, problem, plan, chip),
    }
