"""Candidate block-plan enumeration + per-kernel defaults.

Candidates respect two hard constraints the kernels assert: every
block must divide its dimension evenly, and tiles should stay in the
TPU-native family (lane dim 128; sublane multiples of 8) when the
problem allows it.  Enumeration is deliberately small — the analytic
model (cost_model.py) prunes and measurement picks — so an exhaustive
sweep is never needed to get a good plan.

``defaults_for`` is the plan a wrapper uses with no cache entry and no
explicit args; it reproduces the kernels' historical hand-picked
defaults on the shapes they were picked for.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

from repro.tuning.plan import (AttentionProblem, MatmulProblem, Plan,
                               Problem, WkvProblem)


def _tile_candidates(dim: int,
                     tiles: Tuple[int, ...] = (128, 256, 512)) -> List[int]:
    """Preferred tile sizes that divide ``dim``, plus ``dim`` itself
    when it is small enough to be a single block."""
    cands = {t for t in tiles if t <= dim and dim % t == 0}
    if dim <= max(tiles):
        cands.add(dim)
    if not cands:           # dim divides none of the standard tiles
        cands.add(dim)
    return sorted(cands)


def _default_tile(dim: int, cap: int,
                  tiles: Tuple[int, ...] = (128, 256, 512)) -> int:
    """Largest standard tile <= cap that divides dim (the hand-picked
    default policy, made shape-safe)."""
    fitting = [t for t in _tile_candidates(dim, tiles) if t <= cap]
    return max(fitting) if fitting else dim


# ------------------------------------------------------------ spm_matmul

def _enum_spm_matmul(p: MatmulProblem) -> List[Plan]:
    bks = [0] + [b for b in (256, 512) if b < p.k and p.k % b == 0]
    return [{"bm": bm, "bn": bn, "bk": bk}
            for bm in _tile_candidates(p.m)
            for bn in _tile_candidates(p.n)
            for bk in bks]


def _default_spm_matmul(p: MatmulProblem) -> Plan:
    return {"bm": _default_tile(p.m, 256), "bn": _default_tile(p.n, 256),
            "bk": 0}


# ------------------------------------------------------ flash_attention

_ATTN_TILES = (64, 128, 256, 512)


def _enum_flash(p: AttentionProblem) -> List[Plan]:
    return [{"bq": bq, "bk": bk}
            for bq in _tile_candidates(p.seq_q, _ATTN_TILES)
            for bk in _tile_candidates(p.seq_k, _ATTN_TILES)]


def _default_flash(p: AttentionProblem) -> Plan:
    return {"bq": _default_tile(p.seq_q, 256, _ATTN_TILES),
            "bk": _default_tile(p.seq_k, 256, _ATTN_TILES)}


# ----------------------------------------------------------------- wkv6

_WKV_TILES = (32, 64, 128, 256)


def _enum_wkv(p: WkvProblem) -> List[Plan]:
    return [{"chunk": c} for c in _tile_candidates(p.seq, _WKV_TILES)]


def _default_wkv(p: WkvProblem) -> Plan:
    return {"chunk": _default_tile(p.seq, 128, _WKV_TILES)}


# -------------------------------------------------------------- registry

@dataclass(frozen=True)
class KernelTuneSpec:
    """Tuning hooks for one registered kernel."""
    name: str
    param_names: Tuple[str, ...]
    defaults: Callable[[Problem], Plan]
    enumerate: Callable[[Problem], List[Plan]]


TUNE_SPECS: Dict[str, KernelTuneSpec] = {
    "spm_matmul": KernelTuneSpec(
        "spm_matmul", ("bm", "bn", "bk"),
        _default_spm_matmul, _enum_spm_matmul),
    "flash_attention": KernelTuneSpec(
        "flash_attention", ("bq", "bk"),
        _default_flash, _enum_flash),
    "wkv6": KernelTuneSpec(
        "wkv6", ("chunk",), _default_wkv, _enum_wkv),
}


def defaults_for(kernel: str, problem: Problem) -> Plan:
    return dict(TUNE_SPECS[kernel].defaults(problem))


def enumerate_candidates(kernel: str, problem: Problem) -> List[Plan]:
    cands = TUNE_SPECS[kernel].enumerate(problem)
    default = TUNE_SPECS[kernel].defaults(problem)
    if default not in cands:
        cands.append(default)
    return cands
