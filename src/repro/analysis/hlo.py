"""HLO-text analysis: collective-op inventory with byte counts.

cost_analysis() does not report collective traffic, so we parse the
compiled (post-SPMD-partitioner) HLO and sum the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.  Ops inside ``while`` bodies appear once in the
text; the roofline composer multiplies per-unit pieces by their trip
counts (see analysis/pieces.py), mirroring the paper's compositional
timing analysis.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

import numpy as np

from repro.compat import cost_analysis

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# e.g.:  %all-reduce.1 = bf16[8,128]{1,0} all-reduce(...)
#        ROOT %x = (f32[2], f32[2]) all-to-all(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",") if d],
                            dtype=np.int64))
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """-> {kind: {count, bytes}} summed over every appearance."""
    stats = defaultdict(lambda: {"count": 0, "bytes": 0})
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += shape_bytes(shape_str)
    return {k: dict(v) for k, v in stats.items()}


def total_collective_bytes(stats: Dict) -> int:
    return int(sum(v["bytes"] for v in stats.values()))


def summarize_compiled(compiled) -> Dict:
    """Extract a JSON-able record from a compiled executable."""
    rec = {}
    try:
        ca = cost_analysis(compiled)
        rec["flops"] = ca.get("flops", 0.0)
        rec["bytes_accessed"] = ca.get("bytes accessed", 0.0)
        rec["transcendentals"] = ca.get("transcendentals", 0.0)
    except Exception as e:  # pragma: no cover
        rec["cost_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        rec["memory_error"] = repr(e)
    try:
        txt = compiled.as_text()
        rec["collectives"] = collective_stats(txt)
        rec["collective_bytes"] = total_collective_bytes(rec["collectives"])
    except Exception as e:  # pragma: no cover
        rec["collective_error"] = repr(e)
    return rec
