"""Analytic parameter / FLOP model.

MODEL_FLOPS follows the assignment: 6*N*D for training (N = active
params, D = tokens), 2*N*D for inference forward passes.  For MoE, N
counts each token's routed experts (top_k + shared), not the full
expert pool.  Used for the §Roofline "useful compute" ratio against the
compiled HLO FLOPs.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _tree_param_count(cfg: ModelConfig, skip_prefix: Tuple[str, ...] = ()):
    from repro.models import lm as lm_mod
    from repro.models.spec import is_par
    import jax

    spec = lm_mod.model_spec(cfg)
    total = 0
    expert = 0
    flat = jax.tree_util.tree_flatten_with_path(
        spec, is_leaf=is_par)[0]
    for path, p in flat:
        n = int(np.prod(p.shape, dtype=np.int64))
        keys = "/".join(str(getattr(k, "key", k)) for k in path)
        total += n
        if "/we_" in keys or keys.startswith("we_"):
            expert += n
    return total, expert


def param_count(cfg: ModelConfig) -> int:
    """Total parameters (embeddings included)."""
    total, _ = _tree_param_count(cfg)
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token: full experts replaced by top_k-worth."""
    total, expert = _tree_param_count(cfg)
    if cfg.moe is None or expert == 0:
        return total
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return int(total - expert + expert * frac)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global MODEL_FLOPS for one step of this cell."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens = shape.global_batch * max(
                256, shape.seq_len // cfg.encdec.dec_len_ratio)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens = shape.global_batch * max(
                256, shape.seq_len // cfg.encdec.dec_len_ratio)
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
