"""Three-term roofline analysis per (arch x shape x mesh).

Hardware constants (assignment-specified, TPU v5e-class):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

``cost_analysis``/HLO numbers from a jitted SPMD program are PER-DEVICE
(verified empirically), so:
    compute term    = flops_per_device / peak
    memory term     = bytes_per_device / hbm_bw
    collective term = collective_bytes_per_device / link_bw
which equal the assignment's global/(chips*bw) forms.  The collective
term conservatively assumes a single ICI link is utilized per chip.
"""
from __future__ import annotations

from typing import Dict

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link


def roofline_terms(flops_dev: float, bytes_dev: float,
                   coll_bytes_dev: float) -> Dict[str, float]:
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_bytes_dev / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    terms.update({
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        # fraction of the bound that is useful compute — the roofline
        # fraction we hillclimb (1.0 = perfectly compute-bound)
        "roofline_fraction": compute_s / bound if bound > 0 else 0.0,
    })
    return terms


def kernel_bound_s(flops: float, bytes_accessed: float, *,
                   peak_flops: float = PEAK_FLOPS,
                   hbm_bw: float = HBM_BW,
                   mxu_eff: float = 1.0,
                   hbm_derate: float = 1.0) -> float:
    """Two-term roofline bound for a single fused kernel, in seconds.

    The per-device composition above is for whole programs; a single
    Pallas kernel has no collective term, so its bound is just
    max(compute, memory).  ``mxu_eff``/``hbm_derate`` let callers apply
    worst-case derates (core.tpu_mapping.TPUChip) — the autotuner's
    analytic pruner ranks candidate block plans with this.
    """
    return max(flops / (peak_flops * mxu_eff),
               bytes_accessed / (hbm_bw * hbm_derate))


def compose_pieces(piece_records) -> Dict[str, float]:
    """Sum (cost x multiplier) over piece records from the runner."""
    tot = {"flops": 0.0, "bytes_accessed": 0.0, "collective_bytes": 0.0}
    for rec in piece_records:
        m = rec["multiplier"]
        tot["flops"] += m * rec.get("flops", 0.0)
        tot["bytes_accessed"] += m * rec.get("bytes_accessed", 0.0)
        tot["collective_bytes"] += m * rec.get("collective_bytes", 0.0)
    return tot
