"""Itemized analytic HBM-traffic model (bytes per device per step).

The CPU-backend HLO 'bytes accessed' is an UNFUSED upper bound: it
round-trips every intermediate (e.g. the full attention score matrix)
through memory, which a TPU program with flash-tiled kernels (see
kernels/) never does.  This model itemizes the traffic a deployed
program pays:

  * weights: fwd read + bwd read (+ remat re-read) per step,
  * optimizer: fp32 moments read+write, grads read, params read+write,
  * activations: residual stream + block internals per layer, with
    attention at flash cost (K/V re-streamed once per query chunk),
  * embeddings/logits: token gathers + chunked logits,
  * KV cache read/write for decode.

All terms are per device (batch/seq/vocab shards divided out).
Reported in §Roofline alongside the HLO upper bound.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.analysis.flops import active_param_count, param_count

BF16 = 2
F32 = 4


def _per_dev(x: float, shards: int) -> float:
    return x / shards


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int = 256,
                   chunk_q: int = 512,
                   weight_shards: int = 0) -> Dict[str, float]:
    """weight_shards: how many ways the weights are sharded (defaults to
    `chips`; 16 under the serving_tp variant where weights live on the
    model axis only)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        S_dec = max(256, S // cfg.encdec.dec_len_ratio)
    else:
        S_dec = S
    d = cfg.d_model
    L = cfg.num_layers
    n_params = param_count(cfg)
    n_active = active_param_count(cfg)

    ws = weight_shards or chips
    items: Dict[str, float] = {}

    if shape.kind == "train":
        tokens_dev = B * S_dec / 16   # batch sharded on data axis (16)
        act = tokens_dev * d * BF16
        # weights: fwd + bwd + remat recompute reads, grad write (f32)
        items["weights"] = 3 * (n_active * BF16 / ws) \
            + n_params * F32 / chips
        # optimizer: m,v read+write (f32), grad read, param read+write
        items["optimizer"] = n_params * (4 * F32 + F32 + 2 * BF16) / chips
        # activations: ~12 residual-width tensors per layer fwd +
        # ~2x that for bwd+recompute
        items["activations"] = L * act * 12 * 3
        if cfg.attention is not None:
            a = cfg.attention
            kv_bytes = B / 16 * S_dec * a.num_kv_heads * a.head_dim * BF16
            n_qchunks = max(1, S_dec // chunk_q)
            items["attention_kv_stream"] = L * 2 * kv_bytes * n_qchunks
        # logits: chunked [B,C,V] f32 write+read (fwd+bwd), vocab/16
        items["logits"] = 2 * 2 * tokens_dev * cfg.padded_vocab / 16 * F32
        items["embed_gather"] = 3 * tokens_dev * d * BF16
    elif shape.kind == "prefill":
        tokens_dev = B * S_dec / 16
        act = tokens_dev * d * BF16
        items["weights"] = n_active * BF16 / ws
        items["activations"] = L * act * 12
        if cfg.attention is not None:
            a = cfg.attention
            kv_bytes = B / 16 * S_dec * a.num_kv_heads * a.head_dim * BF16
            n_qchunks = max(1, S_dec // chunk_q)
            items["attention_kv_stream"] = L * 2 * kv_bytes * n_qchunks
            items["kv_cache_write"] = L * 2 * kv_bytes / 16
        items["logits"] = B / 16 * cfg.padded_vocab / 16 * F32
        items["embed_gather"] = tokens_dev * d * BF16
    else:  # decode
        # every weight shard is read once per token
        items["weights"] = n_active * BF16 / ws
        if cfg.attention is not None and cfg.family not in ("rwkv",):
            a = cfg.attention
            kv_global = (B * S * a.num_kv_heads * a.head_dim * BF16
                         * 2 * L)
            items["kv_cache_read"] = kv_global / chips
            items["kv_cache_write"] = B * a.num_kv_heads * a.head_dim \
                * BF16 * 2 * L / 16
        if cfg.family in ("rwkv", "hybrid"):
            # recurrent state read+write
            if cfg.rwkv is not None:
                H = d // cfg.rwkv.head_dim
                st = B * H * cfg.rwkv.head_dim ** 2 * BF16 * L
            else:
                d_in = cfg.ssm.expand * d
                H = d_in // cfg.ssm.head_dim
                st = B * H * cfg.ssm.state_dim * cfg.ssm.head_dim \
                    * BF16 * L
            items["state_rw"] = 2 * st / 16
        bdev = max(1, B // 16)
        items["activations"] = L * bdev * d * BF16 * 12
        items["logits"] = bdev * cfg.padded_vocab / 16 * F32

    items["total"] = sum(items.values())
    return items
