"""Compositional cost pieces — the paper's timing-compositionality
(§3.1) applied to roofline accounting.

``cost_analysis()`` counts a ``lax.scan`` body once (verified
empirically), so a whole-model lowering under-reports FLOPs/bytes/
collectives by the layer count.  Instead of unrolling 95-layer models
at 512 devices, we lower each *repeat unit* separately (with the true
shardings) and compose:

    total = sum over pieces ( piece_cost x multiplier )

Pieces are chosen so that each piece's internal scans are degenerate:
 * attention units lower with chunk_q=chunk_kv=0 (single-block attention
   is FLOP-identical to the chunked schedule),
 * recurrent units (Mamba2/RWKV6) lower at one chunk of sequence with
   multiplier n_units * (S / chunk)  (all their costs are linear in S),
 * zamba2's quadratic shared-attention block is split out as its own
   full-sequence piece,
 * the loss lowers with loss_chunk=0,
 * the optimizer update is one piece over the full parameter tree.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import blocks as blk
from repro.models import lm as lm_mod
from repro.models.lm import RunOptions
from repro.models.spec import shape_tree
from repro.optim.adamw import adamw_init_spec, adamw_update, cosine_lr
from repro.sharding.rules import ShardingRules


@dataclass
class Piece:
    name: str
    multiplier: float
    fn: Callable
    specs: Tuple


def _sds(shape, dtype, rules, axes):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype),
                                sharding=rules.sharding_for(axes, shape))


def _x_spec(cfg, B, S, rules):
    return _sds((B, S, cfg.d_model), cfg.dtype, rules,
                ("batch", None, None))


def _unit_param_specs(cfg, stage: blk.StageDescr, rules):
    unit = {f"pos{i}": blk.layer_spec(cfg, dsc)
            for i, dsc in enumerate(stage.unit)}
    return shape_tree(unit, rules)


def _unit_cache_specs(cfg, stage, B, cache_len, rules):
    unit = {f"pos{i}": blk.layer_cache_spec(cfg, dsc, B, cache_len)
            for i, dsc in enumerate(stage.unit)}
    return shape_tree(unit, rules)


def _shared_specs(cfg, rules):
    from repro.models.spec import stack
    return shape_tree(stack(blk.shared_block_spec(cfg),
                            cfg.ssm.n_shared_blocks), rules)


def _dec_len(cfg, shape) -> int:
    if cfg.family == "encdec":
        return max(256, shape.seq_len // cfg.encdec.dec_len_ratio)
    return shape.seq_len


def _is_recurrent_stage(stage: blk.StageDescr) -> bool:
    return any(d.kind in ("mamba", "rwkv") for d in stage.unit)


def _positions(S):
    return jnp.arange(S, dtype=jnp.int32)


def _unit_fn(cfg, stage, opts, *, train: bool, collect: bool,
             has_shared: bool, has_memory: bool):
    """fn(unit_params, x, x0, shared?, memory?) lowering one unit."""

    def fwd(up, x, x0, shared, memory):
        out, aux, cache = lm_mod._apply_unit_full(
            cfg, up, stage.unit, x, x0, _positions(x.shape[1]), opts,
            collect, memory, shared, jnp.zeros((), jnp.int32), x.shape[1])
        loss = out.astype(jnp.float32).sum() + aux
        return (loss, cache) if collect else loss

    if train:
        argnums = (0, 1) + ((3,) if has_shared else ())

        def step(up, x, x0, shared=None, memory=None):
            return jax.grad(fwd, argnums=argnums)(up, x, x0, shared,
                                                  memory)
    else:
        def step(up, x, x0, shared=None, memory=None):
            return fwd(up, x, x0, shared, memory)
    return step


def _strip_shared_attn(stage: blk.StageDescr) -> blk.StageDescr:
    import dataclasses
    unit = tuple(dataclasses.replace(d, shared_attn=False)
                 for d in stage.unit)
    return blk.StageDescr(stage.n_units, unit)


def _chunked_stage_pieces(cfg, stage, si, B, S, rules, opts, train,
                          collect) -> List[Piece]:
    """Recurrent stage: lower one unit at one chunk of sequence."""
    chunk = (cfg.ssm.chunk_size if cfg.family == "hybrid"
             else cfg.rwkv.chunk_size)
    chunk = min(chunk, S)
    n_chunks = S // chunk
    pieces = []
    # shared-attention applications (zamba2) — full-sequence quadratic
    n_shared_apps = sum(1 for d in stage.unit if d.shared_attn) \
        * stage.n_units
    if n_shared_apps:
        def shared_fn(shared, x, x0):
            def fwd(shared, x, x0):
                sp = blk.tree_index(shared, 0)
                out, _ = lm_mod._shared_block_full(
                    cfg, sp, x, x0, _positions(x.shape[1]),
                    RunOptions(chunk_q=0, chunk_kv=0,
                               shardings=opts.shardings), False)
                return out.astype(jnp.float32).sum()
            if train:
                return jax.grad(fwd, argnums=(0, 1))(shared, x, x0)
            return fwd(shared, x, x0)
        pieces.append(Piece(
            f"stage{si}_shared_attn", n_shared_apps, shared_fn,
            (_shared_specs(cfg, rules), _x_spec(cfg, B, S, rules),
             _x_spec(cfg, B, S, rules))))

    stage1 = blk.StageDescr(1, _strip_shared_attn(
        blk.StageDescr(1, (stage.unit[-1],))).unit)
    n_layers = stage.n_units * stage.unit_len
    fn = _unit_fn(cfg, stage1, opts, train=train, collect=collect,
                  has_shared=False, has_memory=False)
    pieces.append(Piece(
        f"stage{si}_unit_chunk", n_layers * n_chunks, fn,
        (_unit_param_specs(cfg, stage1, rules),
         _x_spec(cfg, B, chunk, rules), _x_spec(cfg, B, chunk, rules))))
    return pieces


def train_pieces(cfg: ModelConfig, shape: ShapeConfig,
                 rules: ShardingRules, opts: RunOptions) -> List[Piece]:
    B, S = shape.global_batch, _dec_len(cfg, shape)
    # exact-FLOP single-block attention + unchunked loss for pieces
    popts = RunOptions(chunk_q=0, chunk_kv=0, loss_chunk=0, remat=False,
                       shardings=opts.shardings, moe_impl=opts.moe_impl)
    pieces: List[Piece] = []

    for si, stage in enumerate(blk.build_stages(cfg)):
        if _is_recurrent_stage(stage):
            pieces += _chunked_stage_pieces(cfg, stage, si, B, S, rules,
                                            popts, True, False)
            continue
        has_mem = any(d.kind == "dec_attn" for d in stage.unit)
        fn = _unit_fn(cfg, stage, popts, train=True, collect=False,
                      has_shared=False, has_memory=has_mem)
        specs = [
            _unit_param_specs(cfg, stage, rules),
            _x_spec(cfg, B, S, rules), _x_spec(cfg, B, S, rules)]
        if has_mem:
            specs.append(None)   # shared placeholder
            specs.append(_x_spec(cfg, B, shape.seq_len, rules))
        pieces.append(Piece(f"stage{si}_unit", stage.n_units, fn,
                            tuple(specs)))

    if cfg.family == "encdec":
        enc = blk.encoder_stage(cfg)
        fn = _unit_fn(cfg, enc, popts, train=True, collect=False,
                      has_shared=False, has_memory=False)
        pieces.append(Piece(
            "encoder_unit", enc.n_units, fn,
            (_unit_param_specs(cfg, enc, rules),
             _x_spec(cfg, B, shape.seq_len, rules),
             _x_spec(cfg, B, shape.seq_len, rules))))

    # embedding + loss (fwd+bwd)
    def embed_loss(ep, tokens, targets, x_fin):
        def fwd(ep, x_fin):
            x = lm_mod._embed(cfg, ep, tokens, None, popts)
            from repro.models.common import rmsnorm
            h = rmsnorm(x_fin, ep["final_norm"])
            loss = lm_mod.lm_loss(cfg, ep, h, targets, popts)
            return loss + jnp.float32(1e-9) * x.astype(jnp.float32).sum()
        return jax.grad(fwd, argnums=(0, 1))(ep, x_fin)

    from repro.models.spec import Par
    ep_spec = {"embed": shape_tree(
        Par((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
            dtype=cfg.dtype), rules),
        "final_norm": shape_tree(
            Par((cfg.d_model,), (None,), dtype="float32"), rules)}
    if not cfg.tie_embeddings:
        ep_spec["lm_head"] = shape_tree(
            Par((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                dtype=cfg.dtype), rules)
    tok = _sds((B, S), jnp.int32, rules, ("batch", None))
    pieces.append(Piece("embed_loss", 1.0, embed_loss,
                        (ep_spec, tok, tok, _x_spec(cfg, B, S, rules))))

    # optimizer update over the whole parameter tree
    tcfg = TrainConfig()
    lr_fn = cosine_lr(tcfg)

    def opt_piece(params, opt_state, grads):
        return adamw_update(grads, opt_state, params, tcfg, lr_fn)

    pspec = shape_tree(lm_mod.model_spec(cfg), rules)
    ospec = shape_tree(adamw_init_spec(lm_mod.model_spec(cfg)), rules)
    pieces.append(Piece("optimizer", 1.0, opt_piece,
                        (pspec, ospec, pspec)))
    return pieces


def prefill_pieces(cfg, shape, rules, opts) -> List[Piece]:
    B, S = shape.global_batch, _dec_len(cfg, shape)
    popts = RunOptions(chunk_q=0, chunk_kv=0, loss_chunk=0, remat=False,
                       shardings=opts.shardings, moe_impl=opts.moe_impl)
    pieces: List[Piece] = []
    for si, stage in enumerate(blk.build_stages(cfg)):
        if _is_recurrent_stage(stage):
            pieces += _chunked_stage_pieces(cfg, stage, si, B, S, rules,
                                            popts, False, False)
            continue
        has_mem = any(d.kind == "dec_attn" for d in stage.unit)
        fn = _unit_fn(cfg, stage, popts, train=False, collect=True,
                      has_shared=False, has_memory=has_mem)
        specs = [_unit_param_specs(cfg, stage, rules),
                 _x_spec(cfg, B, S, rules), _x_spec(cfg, B, S, rules)]
        if has_mem:
            specs.append(None)
            specs.append(_x_spec(cfg, B, shape.seq_len, rules))
        pieces.append(Piece(f"stage{si}_unit", stage.n_units, fn,
                            tuple(specs)))
    if cfg.family == "encdec":
        enc = blk.encoder_stage(cfg)
        fn = _unit_fn(cfg, enc, popts, train=False, collect=False,
                      has_shared=False, has_memory=False)
        pieces.append(Piece(
            "encoder_unit", enc.n_units, fn,
            (_unit_param_specs(cfg, enc, rules),
             _x_spec(cfg, B, shape.seq_len, rules),
             _x_spec(cfg, B, shape.seq_len, rules))))

    def head_fn(ep, tokens, x_fin):
        from repro.models.common import rmsnorm
        x = lm_mod._embed(cfg, ep, tokens, None, popts)
        h = rmsnorm(x_fin[:, -1], ep["final_norm"])
        return lm_mod.compute_logits(cfg, ep, h), x.sum()

    from repro.models.spec import Par
    ep_spec = {"embed": shape_tree(
        Par((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
            dtype=cfg.dtype), rules),
        "final_norm": shape_tree(
            Par((cfg.d_model,), (None,), dtype="float32"), rules)}
    if not cfg.tie_embeddings:
        ep_spec["lm_head"] = shape_tree(
            Par((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                dtype=cfg.dtype), rules)
    tok = _sds((B, S), jnp.int32, rules, ("batch", None))
    pieces.append(Piece("embed_head", 1.0, head_fn,
                        (ep_spec, tok, _x_spec(cfg, B, S, rules))))
    return pieces


def decode_pieces(cfg, shape, rules, opts) -> List[Piece]:
    B = shape.global_batch
    cache_len = shape.seq_len
    popts = RunOptions(chunk_q=0, chunk_kv=0, shardings=opts.shardings,
                       moe_impl=opts.moe_impl)
    pieces: List[Piece] = []
    for si, stage in enumerate(blk.build_stages(cfg)):
        has_shared = any(d.shared_attn for d in stage.unit)

        def mk(stage_, has_shared_):
            def fn(up, cache_u, x, x0, shared=None):
                out, nc = lm_mod._apply_unit_decode(
                    cfg, up, stage_.unit, x, x0, jnp.int32(cache_len - 1),
                    popts, cache_u, shared, jnp.zeros((), jnp.int32))
                return out, nc
            return fn

        specs = [
            _unit_param_specs(cfg, stage, rules),
            _unit_cache_specs(cfg, stage, B, cache_len, rules),
            _x_spec(cfg, B, 1, rules), _x_spec(cfg, B, 1, rules)]
        if has_shared:
            specs.append(_shared_specs(cfg, rules))
        pieces.append(Piece(f"stage{si}_unit", stage.n_units,
                            mk(stage, has_shared), tuple(specs)))

    def head_fn(ep, token, x_fin):
        from repro.models.common import rmsnorm
        x = lm_mod._embed(cfg, ep, token[:, None], None, popts)
        h = rmsnorm(x_fin[:, 0], ep["final_norm"])
        return lm_mod.compute_logits(cfg, ep, h), x.sum()

    from repro.models.spec import Par
    ep_spec = {"embed": shape_tree(
        Par((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
            dtype=cfg.dtype), rules),
        "final_norm": shape_tree(
            Par((cfg.d_model,), (None,), dtype="float32"), rules)}
    if not cfg.tie_embeddings:
        ep_spec["lm_head"] = shape_tree(
            Par((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                dtype=cfg.dtype), rules)
    tok = _sds((B,), jnp.int32, rules, ("batch",))
    pieces.append(Piece("embed_head", 1.0, head_fn,
                        (ep_spec, tok, _x_spec(cfg, B, 1, rules))))
    return pieces


def cost_pieces(cfg: ModelConfig, shape: ShapeConfig,
                rules: ShardingRules, opts: RunOptions) -> List[Piece]:
    if shape.kind == "train":
        return train_pieces(cfg, shape, rules, opts)
    if shape.kind == "prefill":
        return prefill_pieces(cfg, shape, rules, opts)
    return decode_pieces(cfg, shape, rules, opts)
