from repro.sharding.rules import (ShardingRules, make_rules,
                                  logical_to_pspec, named_sharding)

__all__ = ["ShardingRules", "make_rules", "logical_to_pspec",
           "named_sharding"]
