"""Logical-axis -> mesh-axis sharding rules.

This is the datacenter-scale version of the paper's partitioning: weights
are *stationary* in per-device shards (the paper pins B-matrix column
blocks in each core's scratchpad), activations stream through, partial
results reduce.  Rules:

  - big weight matrices are 2D-sharded: feature/head/expert/vocab dims on
    the ``tensor`` ("model") axis, the d_model dim on the ``fsdp``
    ("data") axis (ZeRO-style),
  - activations shard batch on ("pod","data"),
  - long-context decode shards the KV-cache *sequence* on "data",
  - any dim that does not divide its mesh axes is replicated
    (divisibility fallback; see DESIGN.md §4/§5).

Every rule resolution is per-parameter stateful: a mesh axis is used at
most once per array (GSPMD requirement).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as PS

# Logical axis vocabulary used by the model param/activation specs.
#   vocab, embed (d_model inside weights), ffn, heads, kv_heads, head_dim,
#   experts, expert_ff, stack (scan-stacked layers), batch, seq, kv_seq,
#   state, conv_k, group, capacity
# Anything unlisted resolves to replicated.


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    batch_axes: Tuple[str, ...]        # activations' batch dim
    fsdp_axes: Tuple[str, ...]         # weights' d_model dim (ZeRO)
    tensor_axes: Tuple[str, ...]       # weights' feature dims (Megatron)
    kv_seq_axes: Tuple[str, ...] = ()  # KV-cache sequence dim (long ctx)
    # optimization levers (see launch/specs.py variants + §Perf):
    head_dim_axes: Tuple[str, ...] = ()   # shard head_dim when heads
    #                                       don't divide the model axis
    act_seq_axes: Tuple[str, ...] = ()    # sequence parallelism for the
    #                                       residual stream / remat saves

    def _table(self) -> Dict[str, Tuple[str, ...]]:
        return {
            "vocab": self.tensor_axes,
            "embed": self.fsdp_axes,
            "ffn": self.tensor_axes,
            "heads": self.tensor_axes,
            "kv_heads": self.tensor_axes,
            "head_dim": self.head_dim_axes,
            "experts": self.tensor_axes,
            "expert_ff": self.fsdp_axes,
            "batch": self.batch_axes,
            "kv_seq": self.kv_seq_axes,
            "seq": self.act_seq_axes,
        }

    def axis_size(self, names: Tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[n] for n in names], dtype=np.int64)) \
            if names else 1

    def spec_for(self, logical_axes: Sequence[Optional[str]],
                 shape: Sequence[int]) -> PS:
        """Resolve one array's logical axes to a PartitionSpec."""
        table = self._table()
        used: set = set()
        out = []
        for logical, dim in zip(logical_axes, shape):
            entry: Optional[Tuple[str, ...]] = None
            if logical is not None:
                cand = table.get(logical, ())
                if cand and not (set(cand) & used):
                    if dim % self.axis_size(cand) == 0 and dim > 0:
                        entry = cand
            if entry:
                used.update(entry)
                out.append(entry if len(entry) > 1 else entry[0])
            else:
                out.append(None)
        # trim trailing Nones (cosmetic)
        while out and out[-1] is None:
            out.pop()
        return PS(*out)

    def sharding_for(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))


def make_rules(mesh: Mesh, shape_kind: str = "train",
               global_batch: int = 0) -> ShardingRules:
    """Build rules for a mesh and a shape regime.

    shape_kind: train | prefill | decode | long_decode
    """
    names = mesh.axis_names
    has_pod = "pod" in names
    batch: Tuple[str, ...] = (("pod", "data") if has_pod else ("data",))
    fsdp: Tuple[str, ...] = ("data",) if "data" in names else ()
    tensor: Tuple[str, ...] = ("model",) if "model" in names else ()
    kv_seq: Tuple[str, ...] = ()

    if shape_kind == "long_decode" or (
            shape_kind == "decode" and global_batch == 1):
        # batch=1: cannot shard batch; shard the KV sequence over the
        # whole mesh instead (flash-decoding-style partial softmax).
        batch = ()
        kv_seq = tuple(a for a in ("data", "model") if a in names)
    else:
        if shape_kind in ("decode", "prefill"):
            # KV heads rarely divide the model axis (GQA); shard the
            # cache sequence dim on "model" instead — 16x cache-memory
            # saving, and decode attention becomes a sharded
            # flash-decode (partial-softmax combine via GSPMD).
            kv_seq = ("model",) if "model" in names else ()
        # shard batch only if divisible; else fall back to data-only
        bsz = global_batch
        if bsz and has_pod:
            full = int(np.prod([mesh.shape[a] for a in batch]))
            if bsz % full != 0:
                batch = ("data",)
    return ShardingRules(mesh=mesh, batch_axes=batch, fsdp_axes=fsdp,
                         tensor_axes=tensor, kv_seq_axes=kv_seq)


def logical_to_pspec(tree_axes, tree_shapes, rules: ShardingRules):
    """Map a pytree of logical-axes tuples + shapes to PartitionSpecs."""
    return jax.tree.map(
        lambda axes, shp: rules.spec_for(axes, shp), tree_axes, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def named_sharding(rules: ShardingRules, spec: PS) -> NamedSharding:
    return NamedSharding(rules.mesh, spec)
