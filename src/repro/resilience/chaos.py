"""Deterministic fault injection (the chaos harness).

The paper's thesis is that predictability comes from enumerating every
timing scenario ahead of time; this module applies the same doctrine to
*failures*: a :class:`FaultPlan` is a seeded, step-indexed schedule of
faults that the training loop consults at each step boundary, so a
chaos run is exactly reproducible — rerunning the same plan injects the
same faults at the same steps with the same corrupted bytes.

Fault taxonomy (``Fault.kind``):

==============  ======================================================
``preempt``     SIGTERM-equivalent: trips the PreemptionGuard, the
                loop checkpoints (blocking) and exits cleanly.
``nan_loss``    poisons the loss/gradients of one step with NaN
                (via the train step's ``loss_scale`` input); the
                non-finite guard must discard the update and retry.
``straggler``   sleeps ``duration_s`` inside the step so the
                StragglerMonitor/deadline machinery sees a real
                outlier.
``io_error``    arms a :class:`TransientIOFault` hook on the
                checkpoint manager: the next ``count`` I/O ops raise
                ``OSError`` and must be absorbed by retry_transient.
``ckpt_corrupt``  corrupts the newest on-disk checkpoint
                (``mode`` selects manifest/array/truncate/partial);
                restore must fall back to the previous intact one.
``cache_corrupt`` overwrites the tuning plan cache with garbage;
                the cache must degrade to empty, not crash.
==============  ======================================================

Every injection is emitted as an ``obs`` instant on the ``chaos``
track (``chaos_<kind>``), so a Chrome trace of a chaos run shows the
fault next to the recovery it provoked.

This module is accelerator-free on purpose (stdlib only): fault
planning must work — and be unit-testable — without importing jax.
"""
from __future__ import annotations

import json
import pathlib
import random
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence

FAULT_KINDS = ("preempt", "nan_loss", "straggler", "io_error",
               "ckpt_corrupt", "cache_corrupt")

CKPT_CORRUPT_MODES = ("manifest", "array", "truncate", "partial",
                      "latest")


@dataclass(frozen=True)
class Fault:
    """One scheduled injection.

    ``step``       the trainer step at whose *start* the fault fires,
    ``kind``       one of :data:`FAULT_KINDS`,
    ``mode``       sub-mode for ``ckpt_corrupt`` (see
                   :func:`corrupt_checkpoint`) / ``cache_corrupt``,
    ``duration_s`` injected stall for ``straggler``,
    ``count``      consecutive failures for ``io_error``.
    """

    step: int
    kind: str
    mode: str = ""
    duration_s: float = 0.0
    count: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; "
                f"taxonomy: {FAULT_KINDS}")


class FaultPlan:
    """Seeded, one-shot schedule of faults.

    ``take(step)`` pops (and records) every not-yet-fired fault
    scheduled at ``step`` — one-shot semantics matter: a ``nan_loss``
    step is *retried* by the trainer, and the retry must see a clean
    step, exactly like a transient bit-flip would behave.
    """

    def __init__(self, faults: Sequence[Fault], seed: int = 0,
                 trace: Optional[Any] = None):
        self._pending: List[Fault] = sorted(faults,
                                            key=lambda f: f.step)
        self.fired: List[Fault] = []
        self.rng = random.Random(seed)
        self.trace = trace          # obs.TraceRecorder (or None)

    def take(self, step: int) -> List[Fault]:
        due = [f for f in self._pending if f.step == step]
        if not due:
            return []
        self._pending = [f for f in self._pending if f.step != step]
        self.fired.extend(due)
        if self.trace is not None:
            for f in due:
                self.trace.instant(
                    f"chaos_{f.kind}", track="chaos", step=f.step,
                    mode=f.mode, duration_s=f.duration_s,
                    count=f.count)
        return due

    @property
    def pending(self) -> List[Fault]:
        return list(self._pending)

    def done(self) -> bool:
        return not self._pending


class TransientIOFault:
    """Injectable I/O fault hook: raises ``OSError`` for the first
    ``count`` matching operations, then heals — the shape of a blip
    that :func:`~repro.resilience.retry.retry_transient` must absorb.

    Attach to ``CheckpointManager.fault_hook`` or
    ``PlanCache.fault_hook``; the hook is called as ``hook(op, path)``
    before each I/O primitive (``op`` in {save_array, write_manifest,
    read_manifest, read_array, read_cache}).
    """

    def __init__(self, count: int = 1, op_match: str = ""):
        self.remaining = count
        self.op_match = op_match
        self.raised = 0

    def __call__(self, op: str, path: Any) -> None:
        if self.remaining > 0 and (not self.op_match
                                   or self.op_match in op):
            self.remaining -= 1
            self.raised += 1
            raise OSError(
                f"injected transient I/O error ({op} on {path})")


def apply_offline_fault(fault: Fault, ckpt_dir=None, cache_path=None,
                        trace: Optional[Any] = None,
                        rng: Optional[random.Random] = None):
    """Apply a disk-damage fault *between* runs (crash-window chaos:
    the damage a dying host leaves behind).  Emits the same
    ``chaos_<kind>`` instant a live :class:`FaultPlan` would, so the
    trace of the recovering run still shows the fault it recovered
    from.  Returns the corrupted checkpoint step (ckpt_corrupt) or
    None."""
    if trace is not None:
        trace.instant(f"chaos_{fault.kind}", track="chaos",
                      step=fault.step, mode=fault.mode)
    if fault.kind == "ckpt_corrupt":
        return corrupt_checkpoint(ckpt_dir, mode=fault.mode or "array",
                                  rng=rng)
    if fault.kind == "cache_corrupt":
        corrupt_plan_cache(cache_path, mode=fault.mode or "garbage")
        return None
    raise ValueError(
        f"{fault.kind!r} is a live fault; schedule it on a FaultPlan")


# --------------------------------------------------------------------
# corruption primitives (the disk-damage half of the taxonomy)


def _newest_step_dir(ckpt_dir: pathlib.Path) -> pathlib.Path:
    dirs = sorted((p for p in ckpt_dir.glob("step_*") if p.is_dir()),
                  key=lambda p: int(p.name.split("_")[1]))
    if not dirs:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    return dirs[-1]


def corrupt_checkpoint(ckpt_dir, step: Optional[int] = None,
                       mode: str = "array",
                       rng: Optional[random.Random] = None) -> int:
    """Deterministically damage one checkpoint; returns the step hit.

    modes: ``manifest`` (garbage JSON), ``array`` (flip bytes mid-file
    — caught only by checksums), ``truncate`` (half the array file —
    partial write), ``partial`` (manifest deleted — interrupted save),
    ``latest`` (the latest pointer names a step that does not exist).
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    rng = random.Random(0xBADF00D) if rng is None else rng
    d = (ckpt_dir / f"step_{step}" if step is not None
         else _newest_step_dir(ckpt_dir))
    if not d.is_dir():
        raise FileNotFoundError(d)
    hit = int(d.name.split("_")[1])
    if mode == "manifest":
        (d / "manifest.json").write_bytes(b'{"step": garbage')
    elif mode == "array":
        f = d / "arr_0.npy"
        blob = bytearray(f.read_bytes())
        # flip bytes in the payload, past the .npy header
        for _ in range(8):
            i = rng.randrange(min(128, len(blob) - 1), len(blob))
            blob[i] ^= 0xFF
        f.write_bytes(bytes(blob))
    elif mode == "truncate":
        f = d / "arr_0.npy"
        f.write_bytes(f.read_bytes()[:max(1, f.stat().st_size // 2)])
    elif mode == "partial":
        (d / "manifest.json").unlink()
    elif mode == "latest":
        (ckpt_dir / "latest").write_text(str(hit + 1_000_000))
    else:
        raise ValueError(f"unknown ckpt_corrupt mode {mode!r}; "
                         f"modes: {CKPT_CORRUPT_MODES}")
    return hit


def corrupt_plan_cache(path, mode: str = "garbage") -> None:
    """Damage the tuning plan cache file (created if absent).

    ``garbage`` — not JSON at all; ``schema`` — valid JSON, wrong
    shape.  Either way PlanCache must warn once and act empty.
    """
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    if mode == "garbage":
        p.write_bytes(b"\x00\xffnot json at all\x9c")
    elif mode == "schema":
        p.write_text(json.dumps({"schema_version": -1, "plans": 7}))
    else:
        raise ValueError(f"unknown cache_corrupt mode {mode!r}")
