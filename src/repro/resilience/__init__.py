"""Resilience: deterministic chaos + the recovery machinery it tests.

The MultiVic doctrine — enumerate every scenario statically so nothing
at runtime is a surprise — applied to failures instead of timing:

- ``chaos``    — seeded :class:`FaultPlan` fault injection (preemption,
  checkpoint/plan-cache corruption, stragglers, NaN losses, transient
  I/O errors) plus the corruption primitives; every injection is an
  ``obs`` instant so traces show fault and recovery side by side.
- ``retry``    — :func:`retry_transient`, jittered-exponential-backoff
  retry for transient I/O (checkpoint writes, plan-cache reads).
- ``deadline`` — :class:`DeadlineMonitor`, the WCET-derived per-step
  deadline with the record → warn → shed degradation ladder used by
  ``launch/serve``.

Accelerator-free by policy (enforced by tests/test_repo_hygiene.py):
fault planning and degradation policy import no jax.
"""
from repro.resilience.chaos import (CKPT_CORRUPT_MODES, FAULT_KINDS,
                                    Fault, FaultPlan, TransientIOFault,
                                    apply_offline_fault,
                                    corrupt_checkpoint,
                                    corrupt_plan_cache)
from repro.resilience.deadline import DeadlineMonitor
from repro.resilience.retry import RetriesExhausted, retry_transient

__all__ = [
    "CKPT_CORRUPT_MODES",
    "FAULT_KINDS",
    "DeadlineMonitor",
    "Fault",
    "FaultPlan",
    "RetriesExhausted",
    "TransientIOFault",
    "apply_offline_fault",
    "corrupt_checkpoint",
    "corrupt_plan_cache",
    "retry_transient",
]
