"""Deadline-aware graceful degradation for serving.

The paper gives every decode step a static WCET bound; a production
server turns that bound into a *deadline* and must have a pre-planned
answer for overruns — bounded degradation, never a surprise.  The
ladder here is deliberately boring and monotone:

  ``record``  first overruns: count them, emit an instant, carry on.
  ``warn``    ``warn_after`` consecutive overruns: the operator-visible
              escalation (callers typically log).
  ``shed``    ``shed_after`` consecutive overruns: the caller should
              shed load (halve the batch, drop requests) to get back
              under the deadline.  The consecutive counter resets so
              the smaller batch gets a fresh chance before the ladder
              escalates again.

Meeting the deadline resets the ladder.  Every rung fires a
``deadline_<action>`` instant on the ``deadline`` track so traces show
the overrun next to the degradation it triggered.

Accelerator-free on purpose: the policy must be unit-testable with
synthetic durations, no jax required.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional


@dataclass
class DeadlineMonitor:
    deadline_s: float
    warn_after: int = 2         # consecutive overruns before "warn"
    shed_after: int = 4         # consecutive overruns before "shed"
    trace: Optional[Any] = None  # obs.TraceRecorder
    overruns: int = 0
    consecutive: int = 0
    worst_overrun_s: float = 0.0
    actions: Dict[str, int] = field(default_factory=lambda: {
        "record": 0, "warn": 0, "shed": 0})

    def __post_init__(self):
        assert self.deadline_s > 0, self.deadline_s
        assert 1 <= self.warn_after <= self.shed_after, (
            self.warn_after, self.shed_after)

    def observe(self, step: int, dt_s: float) -> str:
        """Feed one measured step; returns the action for the caller:
        ``ok`` | ``record`` | ``warn`` | ``shed``."""
        if dt_s <= self.deadline_s:
            self.consecutive = 0
            return "ok"
        self.overruns += 1
        self.consecutive += 1
        self.worst_overrun_s = max(self.worst_overrun_s,
                                   dt_s - self.deadline_s)
        if self.consecutive >= self.shed_after:
            action = "shed"
            self.consecutive = 0    # fresh chance post-degradation
        elif self.consecutive >= self.warn_after:
            action = "warn"
        else:
            action = "record"
        self.actions[action] += 1
        if self.trace is not None:
            self.trace.instant(f"deadline_{action}", track="deadline",
                               step=step, step_s=dt_s,
                               deadline_s=self.deadline_s)
        return action

    def summary(self) -> Dict[str, Any]:
        return {"deadline_s": self.deadline_s,
                "overruns": self.overruns,
                "worst_overrun_s": self.worst_overrun_s,
                **{f"n_{k}": v for k, v in self.actions.items()}}
