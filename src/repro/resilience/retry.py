"""Retry with jittered exponential backoff for *transient* failures.

Scope discipline: this wraps only operations whose failures are
plausibly transient (filesystem hiccups, NFS timeouts — `OSError`
family).  Corruption is NOT transient: a checksum mismatch or a
mis-shaped manifest will fail identically on every attempt, so those
raise distinct exception types that deliberately do not appear in
``retry_on`` (checkpoint fallback handles them instead).

The backoff jitter is drawn from a *seeded* RNG by default: two runs
of the same fault plan retry at the same simulated schedule, which is
what makes the chaos harness deterministic end-to-end.
"""
from __future__ import annotations

import random
import time
from typing import Any, Callable, Optional, Tuple, Type

# module-level seeded stream: deterministic across runs, shared across
# call sites within one process (the order of I/O ops is itself
# deterministic under a fault plan)
_JITTER_RNG = random.Random(0xA11CE)


class RetriesExhausted(RuntimeError):
    """All attempts failed; ``__cause__`` is the last underlying error."""


def retry_transient(
    fn: Callable[[], Any],
    attempts: int = 3,
    base_delay: float = 0.01,
    max_delay: float = 1.0,
    retry_on: Tuple[Type[BaseException], ...] = (OSError,),
    give_up_on: Tuple[Type[BaseException], ...] = (),
    jitter: float = 0.5,
    rng: Optional[random.Random] = None,
    on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
) -> Any:
    """Call ``fn()`` up to ``attempts`` times, backing off between
    failures by ``base_delay * 2**k`` scaled by up to ``1 + jitter``
    (capped at ``max_delay``).

    ``on_retry(attempt, exc, delay_s)`` fires before each sleep — the
    checkpoint layer uses it to emit an ``io_retry`` trace instant so
    recoveries are visible in the Chrome trace.  The final failure
    re-raises the underlying exception wrapped in
    :class:`RetriesExhausted` so callers can distinguish "gave up"
    from a first-try hard error.

    ``give_up_on`` carves exceptions back out of ``retry_on``:
    ``FileNotFoundError`` is an ``OSError``, but a missing file is
    deterministic damage, not a blip — retrying it only delays the
    corruption handler.
    """
    assert attempts >= 1, attempts
    rng = _JITTER_RNG if rng is None else rng
    delay = base_delay
    for attempt in range(1, attempts + 1):
        try:
            return fn()
        except give_up_on:
            raise
        except retry_on as e:
            if attempt == attempts:
                raise RetriesExhausted(
                    f"{attempts} attempts failed; last: {e!r}") from e
            d = min(max_delay, delay) * (1.0 + jitter * rng.random())
            if on_retry is not None:
                on_retry(attempt, e, d)
            sleep(d)
            delay *= 2.0
