"""Checkpointing for fault-tolerant training (no orbax dependency).

Guarantees:
  * atomicity: a checkpoint directory is written under a tmp name and
    os.rename'd into place — a crash mid-save never corrupts `latest`,
  * async: saves run on a background thread from host copies so the
    train loop isn't blocked (`save(..., blocking=False)`),
  * re-mesh restore: arrays are stored UNSHARDED per leaf (gathered to
    host); restore applies whatever shardings the new mesh prescribes,
    so an elastic restart on a different device count just works,
  * retention: keep_n newest checkpoints are retained.

Layout:  <dir>/step_<N>/  { manifest.json, arr_<i>.npy ... }
         <dir>/latest     (text file with the step number)
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _bits_dtype(dt: np.dtype) -> np.dtype:
    return {1: np.uint8, 2: np.uint16, 4: np.uint32,
            8: np.uint64}[dt.itemsize]


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        self.wait()               # never overlap two writers (same dir)
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        if blocking:
            self._write(step, host_tree)
            return
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any) -> None:
        leaves, treedef = jax.tree.flatten(host_tree)
        final = self.dir / f"step_{step}"
        tmp = self.dir / f".tmp_step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "treedef": str(treedef),
                    "n_leaves": len(leaves),
                    "dtypes": [str(l.dtype) for l in leaves]}
        for i, leaf in enumerate(leaves):
            # ml_dtypes (bfloat16 etc.) don't survive np.save; store the
            # raw bits as a same-width integer view, dtype in manifest.
            if leaf.dtype.kind not in "fiub":
                leaf = leaf.view(_bits_dtype(leaf.dtype))
            elif str(leaf.dtype) == "bfloat16":
                leaf = leaf.view(np.uint16)
            np.save(tmp / f"arr_{i}.npy", leaf, allow_pickle=False)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic publish
        (self.dir / ".latest_tmp").write_text(str(step))
        os.rename(self.dir / ".latest_tmp", self.dir / "latest")
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self):
        return [int(p.name.split("_")[1])
                for p in self.dir.glob("step_*") if p.is_dir()]

    def latest_step(self) -> Optional[int]:
        f = self.dir / "latest"
        if not f.exists():
            steps = self.all_steps()
            return max(steps) if steps else None
        step = int(f.read_text().strip())
        return step if (self.dir / f"step_{step}").is_dir() else None

    def restore(self, like_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of like_tree; if `shardings` (a
        matching tree of NamedShardings) is given, device_put each leaf
        accordingly — this is the elastic re-mesh path."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        d = self.dir / f"step_{step}"
        leaves_like, treedef = jax.tree.flatten(like_tree)
        manifest = json.loads((d / "manifest.json").read_text())
        assert manifest["n_leaves"] == len(leaves_like), (
            f"checkpoint has {manifest['n_leaves']} leaves, model needs "
            f"{len(leaves_like)}")
        out = []
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] *
                        len(leaves_like))
        dtypes = manifest.get("dtypes")
        for i, (like, sh) in enumerate(zip(leaves_like, shard_leaves)):
            arr = np.load(d / f"arr_{i}.npy")
            if dtypes and str(arr.dtype) != dtypes[i]:
                import ml_dtypes
                arr = arr.view(np.dtype(dtypes[i]) if dtypes[i] in
                               np.sctypeDict else
                               getattr(ml_dtypes, dtypes[i]))
            assert arr.shape == tuple(like.shape), (
                i, arr.shape, like.shape)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=like.dtype))
        return jax.tree.unflatten(treedef, out), step
