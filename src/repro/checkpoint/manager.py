"""Checkpointing for fault-tolerant training (no orbax dependency).

Guarantees:
  * atomicity: a checkpoint directory is written under a tmp name and
    os.rename'd into place — a crash mid-save never corrupts `latest`,
  * async: saves run on a background thread from host copies so the
    train loop isn't blocked (`save(..., blocking=False)`); a failed
    background save is never silent — the exception is captured and
    re-raised from the next `wait()` (or the `save()` that implies it),
  * integrity: `manifest.json` carries a CRC32 per leaf, verified on
    restore; a corrupt/truncated/partial checkpoint raises
    :class:`CheckpointCorruptError`,
  * self-healing restore: `restore(step=None)` walks checkpoints
    newest-first and falls back to the newest *intact* one when
    `latest` or a step dir is damaged (every fallback is an obs
    instant on the ``ckpt`` track),
  * transient-I/O tolerance: every read/write primitive is wrapped in
    `resilience.retry_transient` (OSError family only — corruption is
    not transient and is never retried),
  * re-mesh restore: arrays are stored UNSHARDED per leaf (gathered to
    host); restore applies whatever shardings the new mesh prescribes,
    so an elastic restart on a different device count just works,
  * retention: keep_n newest checkpoints are retained.

Layout:  <dir>/step_<N>/  { manifest.json, arr_<i>.npy ... }
         <dir>/latest     (text file with the step number)
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import warnings
import zlib
from typing import Any, Callable, List, Optional

import jax
import numpy as np

from repro.resilience.retry import retry_transient


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (bad manifest,
    missing/truncated array file, or checksum mismatch)."""


def _bits_dtype(dt: np.dtype) -> np.dtype:
    return {1: np.uint8, 2: np.uint16, 4: np.uint32,
            8: np.uint64}[dt.itemsize]


def _storage_view(leaf: np.ndarray) -> np.ndarray:
    """ml_dtypes (bfloat16 etc.) don't survive np.save; store the raw
    bits as a same-width integer view, dtype in manifest."""
    if leaf.dtype.kind not in "fiub":
        return leaf.view(_bits_dtype(leaf.dtype))
    if str(leaf.dtype) == "bfloat16":
        return leaf.view(np.uint16)
    return leaf


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 trace: Optional[Any] = None,
                 io_attempts: int = 3, io_base_delay: float = 0.005):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.trace = trace              # obs.TraceRecorder (or None)
        self.io_attempts = io_attempts
        self.io_base_delay = io_base_delay
        # chaos seam: called as hook(op, path) before each I/O
        # primitive; a TransientIOFault here must be absorbed by the
        # retry wrapper below
        self.fault_hook: Optional[Callable[[str, Any], None]] = None
        self._thread: Optional[threading.Thread] = None
        self._bg_error: Optional[BaseException] = None

    # ----------------------------------------------------------- obs/io

    def _instant(self, name: str, **args: Any) -> None:
        if self.trace is not None:
            self.trace.instant(name, track="ckpt", **args)

    def _io(self, op: str, path: Any, fn: Callable[[], Any]) -> Any:
        """One retried I/O primitive; retries emit ``io_retry``
        instants so recoveries show up in the trace."""
        def attempt():
            if self.fault_hook is not None:
                self.fault_hook(op, path)
            return fn()

        return retry_transient(
            attempt, attempts=self.io_attempts,
            base_delay=self.io_base_delay,
            give_up_on=(FileNotFoundError,),
            on_retry=lambda k, e, d: self._instant(
                "io_retry", op=op, attempt=k, error=str(e),
                backoff_s=d))

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        self.wait()               # never overlap two writers (same dir)
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        if blocking:
            self._write(step, host_tree)
            return
        self._thread = threading.Thread(
            target=self._write_bg, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join any in-flight background save; if it failed, re-raise
        its exception here (a lost checkpoint must never be silent)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._bg_error is not None:
            err, self._bg_error = self._bg_error, None
            raise err

    def _write_bg(self, step: int, host_tree: Any) -> None:
        try:
            self._write(step, host_tree)
        except BaseException as e:          # noqa: BLE001 — re-raised
            self._bg_error = e              # from wait()

    def _write(self, step: int, host_tree: Any) -> None:
        leaves, treedef = jax.tree.flatten(host_tree)
        final = self.dir / f"step_{step}"
        tmp = self.dir / f".tmp_step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        views = [_storage_view(l) for l in leaves]
        manifest = {"step": step, "treedef": str(treedef),
                    "n_leaves": len(leaves),
                    "dtypes": [str(l.dtype) for l in leaves],
                    "checksums": [zlib.crc32(np.ascontiguousarray(v)
                                             .tobytes()) & 0xFFFFFFFF
                                  for v in views]}
        for i, view in enumerate(views):
            self._io("save_array", tmp / f"arr_{i}.npy",
                     lambda v=view, i=i: np.save(
                         tmp / f"arr_{i}.npy", v, allow_pickle=False))
        self._io("write_manifest", tmp / "manifest.json",
                 lambda: (tmp / "manifest.json").write_text(
                     json.dumps(manifest)))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic publish
        (self.dir / ".latest_tmp").write_text(str(step))
        os.rename(self.dir / ".latest_tmp", self.dir / "latest")
        self._instant("ckpt_saved", step=step, n_leaves=len(leaves))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self):
        return [int(p.name.split("_")[1])
                for p in self.dir.glob("step_*") if p.is_dir()]

    def latest_step(self) -> Optional[int]:
        """Newest step worth *trying* (the ``latest`` pointer if its
        dir exists, else the newest step dir); deep verification
        happens in restore."""
        steps = self.all_steps()
        f = self.dir / "latest"
        if f.exists():
            try:
                step = int(f.read_text().strip())
            except ValueError:
                step = None
            if step is not None and (self.dir / f"step_{step}").is_dir():
                return step
        return max(steps) if steps else None

    def _candidates(self) -> List[int]:
        """Steps to try, newest-first, `latest`-pointer hint first."""
        steps = sorted(self.all_steps(), reverse=True)
        hint = self.latest_step()
        if hint is not None and hint in steps:
            steps.remove(hint)
            steps.insert(0, hint)
        return steps

    def _read_manifest(self, d: pathlib.Path) -> dict:
        try:
            raw = self._io("read_manifest", d / "manifest.json",
                           lambda: (d / "manifest.json").read_text())
            manifest = json.loads(raw)
        except FileNotFoundError as e:
            raise CheckpointCorruptError(
                f"{d}: manifest missing (partial save?)") from e
        except ValueError as e:
            raise CheckpointCorruptError(
                f"{d}: manifest unreadable: {e}") from e
        if not isinstance(manifest, dict) or "n_leaves" not in manifest:
            raise CheckpointCorruptError(f"{d}: manifest mis-shaped")
        return manifest

    def _read_leaf(self, d: pathlib.Path, i: int,
                   manifest: dict) -> np.ndarray:
        path = d / f"arr_{i}.npy"
        try:
            arr = self._io("read_array", path,
                           lambda: np.load(path, allow_pickle=False))
        except FileNotFoundError as e:
            raise CheckpointCorruptError(f"{path}: missing") from e
        except (ValueError, EOFError) as e:
            raise CheckpointCorruptError(
                f"{path}: unreadable ({e})") from e
        sums = manifest.get("checksums")
        if sums is not None:
            got = zlib.crc32(np.ascontiguousarray(arr)
                             .tobytes()) & 0xFFFFFFFF
            if got != sums[i]:
                raise CheckpointCorruptError(
                    f"{path}: checksum mismatch "
                    f"({got:#010x} != {sums[i]:#010x})")
        return arr

    def verify(self, step: int) -> bool:
        """Deep integrity check of one checkpoint; raises
        :class:`CheckpointCorruptError` on any damage."""
        d = self.dir / f"step_{step}"
        if not d.is_dir():
            raise CheckpointCorruptError(f"{d}: no such checkpoint")
        manifest = self._read_manifest(d)
        for i in range(manifest["n_leaves"]):
            self._read_leaf(d, i, manifest)
        return True

    def _restore_step(self, step: int, leaves_like, shard_leaves):
        d = self.dir / f"step_{step}"
        if not d.is_dir():
            raise CheckpointCorruptError(f"{d}: no such checkpoint")
        manifest = self._read_manifest(d)
        assert manifest["n_leaves"] == len(leaves_like), (
            f"checkpoint has {manifest['n_leaves']} leaves, model needs "
            f"{len(leaves_like)}")
        dtypes = manifest.get("dtypes")
        out = []
        for i, (like, sh) in enumerate(zip(leaves_like, shard_leaves)):
            arr = self._read_leaf(d, i, manifest)
            if dtypes and str(arr.dtype) != dtypes[i]:
                import ml_dtypes
                arr = arr.view(np.dtype(dtypes[i]) if dtypes[i] in
                               np.sctypeDict else
                               getattr(ml_dtypes, dtypes[i]))
            if arr.shape != tuple(like.shape):
                raise CheckpointCorruptError(
                    f"{d}/arr_{i}.npy: shape {arr.shape} != "
                    f"{tuple(like.shape)}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=like.dtype))
        return out

    def restore(self, like_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of like_tree; if `shardings` (a
        matching tree of NamedShardings) is given, device_put each leaf
        accordingly — this is the elastic re-mesh path.

        With ``step=None`` this is self-healing: candidates are tried
        newest-first and a corrupt/partial checkpoint falls back to the
        next intact one (instant ``ckpt_fallback`` per skip).  An
        explicit ``step`` is an exact request — corruption raises."""
        leaves_like, treedef = jax.tree.flatten(like_tree)
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] *
                        len(leaves_like))
        candidates = [step] if step is not None else self._candidates()
        assert candidates, "no checkpoint found"
        last_err: Optional[Exception] = None
        for i, s in enumerate(candidates):
            try:
                out = self._restore_step(s, leaves_like, shard_leaves)
                self._instant("ckpt_restored", step=s,
                              fallbacks=i)
                return jax.tree.unflatten(treedef, out), s
            except CheckpointCorruptError as e:
                last_err = e
                if step is not None:
                    raise
                self._instant("ckpt_fallback", bad_step=s,
                              error=str(e))
                warnings.warn(
                    f"checkpoint step {s} is corrupt ({e}); "
                    "falling back to the previous intact one",
                    RuntimeWarning, stacklevel=2)
        raise CheckpointCorruptError(
            f"no intact checkpoint under {self.dir} "
            f"(tried {candidates})") from last_err
