"""spm_matmul: the paper's matmul benchmark (§4.3) as a TPU Pallas
kernel — the MultiVic dataflow translated to the TPU memory hierarchy.

Paper -> TPU mapping:
  B column block resident in a core's scratchpad  -> B tile pinned in
      VMEM for a whole output-column sweep (B-stationary grid order),
  A rows streamed by the management core's DMA    -> A tiles streamed
      HBM->VMEM by the Pallas grid pipeline (double-buffered by the
      compiler — the *static schedule* is the BlockSpec index maps),
  C fragments written back                        -> C tiles to HBM.

Two paths:
  * K fits VMEM (the paper's regime): 2D grid (j, i), i innermost —
    each B block [K, bn] is fetched once and reused for every A tile.
  * large K: 3D grid (j, i, k) with an fp32 VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel_2d(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def _kernel_3d(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "interpret"))
def spm_matmul(a: jax.Array, b: jax.Array, *, bm: int = 256,
               bn: int = 256, bk: int = 0,
               interpret: bool = False) -> jax.Array:
    """C = A @ B with B-stationary VMEM blocking.

    a: [M, K], b: [K, N].  bk == 0 keeps the full K resident (the
    paper's scratchpad-resident B block)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    if bk <= 0 or bk >= k:
        grid = (n // bn, m // bm)      # i (A tiles) innermost
        return pl.pallas_call(
            _kernel_2d,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, k), lambda j, i: (i, 0)),
                pl.BlockSpec((k, bn), lambda j, i: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )(a, b)

    assert k % bk == 0, (k, bk)
    nk = k // bk
    grid = (n // bn, m // bm, nk)
    return pl.pallas_call(
        functools.partial(_kernel_3d, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda j, i, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda j, i, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, i, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b)
