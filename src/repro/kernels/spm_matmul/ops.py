"""jit'd public wrapper for spm_matmul with VMEM-plan checking.

The block plan is validated against the same scratchpad-capacity logic
the paper core uses (core.tpu_mapping) — the BlockSpec IS the static
DMA schedule, so an infeasible plan is a scheduling bug, not a runtime
surprise.

Block-plan resolution (repro.tuning.resolve_plan): explicit ``bm/bn/
bk`` arguments always win; otherwise a tuned plan from the persistent
plan cache is used when one exists for this (shape, dtype,
environment), else the shape-safe defaults.  ``REPRO_AUTOTUNE=0``
disables the cache consult.
"""
from __future__ import annotations

from typing import Optional

from repro.compat import resolve_interpret
from repro.core.tpu_mapping import V5E, TPUChip
from repro.kernels.spm_matmul.spm_matmul import spm_matmul


def vmem_plan(m: int, k: int, n: int, bm: int, bn: int, bk: int = 0,
              elem_bytes: int = 2, chip: TPUChip = V5E) -> dict:
    kk = k if bk <= 0 else bk
    # A tile + B block + C tile, double-buffered A/C
    need = (2 * bm * kk + kk * bn + 2 * bm * bn) * elem_bytes
    return {"vmem_need": need, "vmem_bytes": chip.vmem_bytes,
            "fits": need <= chip.vmem_bytes}


def matmul(a, b, *, bm: Optional[int] = None, bn: Optional[int] = None,
           bk: Optional[int] = None, interpret=None):
    """Public entry point.  interpret=None auto-selects interpret mode
    off-TPU (CPU validation; see EXAMPLE.md)."""
    from repro.tuning import MatmulProblem, resolve_plan
    plan = resolve_plan(
        "spm_matmul",
        MatmulProblem(a.shape[0], a.shape[1], b.shape[1],
                      str(a.dtype)),
        {"bm": bm, "bn": bn, "bk": bk})
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    interpret = resolve_interpret(interpret)
    fits = vmem_plan(a.shape[0], a.shape[1], b.shape[1], bm, bn, bk,
                     a.dtype.itemsize)["fits"]
    if not fits:
        if bk <= 0:
            bk = 512
        while not vmem_plan(a.shape[0], a.shape[1], b.shape[1], bm, bn,
                            bk, a.dtype.itemsize)["fits"] and bk > 128:
            bk //= 2
    return spm_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
