"""jit'd public wrapper for spm_matmul with VMEM-plan checking.

The block plan is validated against the same scratchpad-capacity logic
the paper core uses (core.tpu_mapping) — the BlockSpec IS the static
DMA schedule, so an infeasible plan is a scheduling bug, not a runtime
surprise.
"""
from __future__ import annotations

from repro.compat import resolve_interpret
from repro.core.tpu_mapping import V5E, TPUChip
from repro.kernels.spm_matmul.spm_matmul import spm_matmul


def vmem_plan(m: int, k: int, n: int, bm: int, bn: int, bk: int = 0,
              elem_bytes: int = 2, chip: TPUChip = V5E) -> dict:
    kk = k if bk <= 0 else bk
    # A tile + B block + C tile, double-buffered A/C
    need = (2 * bm * kk + kk * bn + 2 * bm * bn) * elem_bytes
    return {"vmem_need": need, "vmem_bytes": chip.vmem_bytes,
            "fits": need <= chip.vmem_bytes}


def matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 0,
           interpret=None):
    """Public entry point.  interpret=None auto-selects interpret mode
    off-TPU (CPU validation; see EXAMPLE.md)."""
    interpret = resolve_interpret(interpret)
    plan = vmem_plan(a.shape[0], a.shape[1], b.shape[1], bm, bn, bk,
                     a.dtype.itemsize)
    if not plan["fits"]:
        if bk <= 0:
            bk = 512
        while not vmem_plan(a.shape[0], a.shape[1], b.shape[1], bm, bn,
                            bk, a.dtype.itemsize)["fits"] and bk > 128:
            bk //= 2
    return spm_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
