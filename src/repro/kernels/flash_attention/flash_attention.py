"""Flash attention (forward) as a Pallas TPU kernel.

MultiVic mapping: the online-softmax tiles are the scratchpad-resident
working set; K/V tiles stream through VMEM on the compiler-generated
(static) DMA schedule; the (m, l, acc) running statistics live in VMEM
scratch across the innermost (kv) grid dimension — TPU grids execute
sequentially per core, so scratch carries state exactly like a worker
core's accumulator registers.

Supports causal masking and a sliding window (gemma3's local layers)
via position iota; GQA is handled by folding the q-head group into the
batch-like leading grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                          # [bq, D]
    k = k_ref[0]                          # [bk, D]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [bq, bk]

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_i == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float = 0.0, bq: int = 256, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: [B,Sq,H,D]; k,v: [B,Sk,KV,D] -> [B,Sq,H,D].

    The (batch, kv_head, q_group) triple folds into the first grid
    axis; q blocks are the second; kv blocks stream innermost."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale or float(1.0 / np.sqrt(D))
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0

    # fold: q -> [B*KV*G, Sq, D]; k/v -> [B*KV, Sk, D]
    qf = jnp.moveaxis(q.reshape(B, Sq, KV, G, D), 1, 3) \
        .reshape(B * KV * G, Sq, D)
    kf = jnp.moveaxis(k, 1, 2).reshape(B * KV, Sk, D)
    vf = jnp.moveaxis(v, 1, 2).reshape(B * KV, Sk, D)

    nq, nk = Sq // bq, Sk // bk
    grid = (B * KV * G, nq, nk)

    of = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, g=G: (h // g, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, g=G: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)

    o = of.reshape(B, KV, G, Sq, D)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, D)
