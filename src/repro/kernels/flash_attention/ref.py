"""Pure-jnp oracle for flash attention (causal + sliding window GQA)."""
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale=None):
    """q: [B,Sq,H,D]; k,v: [B,Sk,KV,D]; GQA via head grouping."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale or 1.0 / np.sqrt(D)
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32) * scale
    pos_q = jnp.arange(Sq)[:, None]
    pos_k = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= pos_k <= pos_q
    if window > 0:
        ok &= (pos_q - pos_k) < window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, D)
