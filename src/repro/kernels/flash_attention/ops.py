"""jit'd public wrapper for the flash-attention kernel.

Block-plan resolution (repro.tuning.resolve_plan): explicit ``bq/bk``
arguments always win; otherwise a tuned plan from the persistent plan
cache is used when one exists for this (shape, dtype, environment),
else the shape-safe defaults.  ``REPRO_AUTOTUNE=0`` disables the
cache consult.
"""
from __future__ import annotations

from typing import Optional

from repro.compat import resolve_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention


def attention(q, k, v, *, causal=True, window=0, scale=0.0,
              bq: Optional[int] = None, bk: Optional[int] = None,
              interpret=None):
    from repro.tuning import AttentionProblem, resolve_plan
    B, Sq, H, D = q.shape
    plan = resolve_plan(
        "flash_attention",
        AttentionProblem(B, Sq, k.shape[1], H, k.shape[2], D,
                         causal=causal, window=window,
                         dtype=str(q.dtype)),
        {"bq": bq, "bk": bk})
    return flash_attention(q, k, v, causal=causal, window=window,
                           scale=scale, bq=plan["bq"], bk=plan["bk"],
                           interpret=resolve_interpret(interpret))
