"""jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=0, scale=0.0, bq=256,
              bk=256, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention(q, k, v, causal=causal, window=window,
                           scale=scale, bq=bq, bk=bk,
                           interpret=interpret)
