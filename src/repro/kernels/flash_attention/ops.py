"""jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

from repro.compat import resolve_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention


def attention(q, k, v, *, causal=True, window=0, scale=0.0, bq=256,
              bk=256, interpret=None):
    return flash_attention(q, k, v, causal=causal, window=window,
                           scale=scale, bq=bq, bk=bk,
                           interpret=resolve_interpret(interpret))
