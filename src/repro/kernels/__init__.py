"""Pallas kernel registry + conformance table.

Each kernel directory ships <name>.py (the Pallas kernel), ops.py (the
public wrapper with interpret auto-selection) and ref.py (the pure-jnp
oracle).  ``conformance_cases()`` enumerates one deterministic
(kernel, inputs) grid so the tier-1 harness
(tests/kernel_conformance.py) can run EVERY registered kernel in
interpret mode against its oracle under the shared tolerance policy —
registering a kernel here is all a new kernel needs to get correctness
coverage.

Keep cases small: interpret mode executes the grid sequentially on CPU,
so these are semantics checks, not perf runs (benchmarks/ owns timing).

``KERNEL_REGISTRY`` is the authoritative table of public kernel entry
points and their tunable block parameters: the autotuner
(``repro.tuning``) tunes exactly these names, scripts/tune.py and the
kernel benchmark iterate over them, and the wrappers behind
``import_entry`` resolve un-passed block params through the persistent
plan cache (explicit arguments always override).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One public kernel: where its wrapper lives and which kwargs the
    autotuner owns."""
    name: str
    module: str
    func: str
    plan_params: Tuple[str, ...]


KERNEL_REGISTRY: Dict[str, KernelEntry] = {
    "spm_matmul": KernelEntry(
        "spm_matmul", "repro.kernels.spm_matmul.ops", "matmul",
        ("bm", "bn", "bk")),
    "flash_attention": KernelEntry(
        "flash_attention", "repro.kernels.flash_attention.ops",
        "attention", ("bq", "bk")),
    "wkv6": KernelEntry(
        "wkv6", "repro.kernels.wkv6.ops", "wkv", ("chunk",)),
}


def registered_kernels() -> List[str]:
    return sorted(KERNEL_REGISTRY)


def import_entry(name: str) -> Callable[..., Any]:
    """Resolve a registry row to its public wrapper (lazy: importing
    this package must not pull in jax)."""
    entry = KERNEL_REGISTRY[name]
    return getattr(importlib.import_module(entry.module), entry.func)


@dataclasses.dataclass(frozen=True)
class ConformanceCase:
    """One kernel-vs-oracle check.

    ``run_pair`` builds deterministic inputs and returns
    ``(got, want)`` pytrees — got from the Pallas path forced into
    interpret mode, want from the ref.py oracle in fp32.  ``tol``
    overrides the per-dtype policy (conftest.KERNEL_TOLERANCES) for
    kernels whose oracle uses a different accumulation order.
    """
    kernel: str
    case_id: str
    dtype: str
    run_pair: Callable[[], Tuple[Any, Any]]
    tol: Optional[float] = None

    @property
    def id(self) -> str:
        return f"{self.kernel}-{self.case_id}"


def _matmul_case(m, k, n, bm, bn, bk, dtype) -> ConformanceCase:
    def run_pair():
        import jax
        import jax.numpy as jnp

        from repro.kernels.spm_matmul.ops import matmul
        from repro.kernels.spm_matmul.ref import matmul_ref
        dt = jnp.dtype(dtype)
        ka, kb = jax.random.split(jax.random.PRNGKey(m + n + k))
        a = jax.random.normal(ka, (m, k), jnp.float32).astype(dt)
        b = jax.random.normal(kb, (k, n), jnp.float32).astype(dt)
        got = matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
        want = matmul_ref(a, b)
        return got, want

    return ConformanceCase(
        kernel="spm_matmul", dtype=dtype, run_pair=run_pair,
        case_id=f"{m}x{k}x{n}-b{bm}.{bn}.{bk}-{dtype}")


def _flash_case(B, Sq, Sk, H, KV, D, causal, window, bq, bk,
                dtype) -> ConformanceCase:
    def run_pair():
        import jax
        import jax.numpy as jnp

        from repro.kernels.flash_attention.ops import attention
        from repro.kernels.flash_attention.ref import attention_ref
        dt = jnp.dtype(dtype)
        ks = jax.random.split(jax.random.PRNGKey(Sq + H + D), 3)
        q = jax.random.normal(ks[0], (B, Sq, H, D),
                              jnp.float32).astype(dt)
        k = jax.random.normal(ks[1], (B, Sk, KV, D),
                              jnp.float32).astype(dt)
        v = jax.random.normal(ks[2], (B, Sk, KV, D),
                              jnp.float32).astype(dt)
        got = attention(q, k, v, causal=causal, window=window, bq=bq,
                        bk=bk, interpret=True)
        want = attention_ref(q.astype(jnp.float32),
                             k.astype(jnp.float32),
                             v.astype(jnp.float32), causal=causal,
                             window=window)
        return got, want

    tag = "causal" if causal else "full"
    if window:
        tag += f"-w{window}"
    return ConformanceCase(
        kernel="flash_attention", dtype=dtype, run_pair=run_pair,
        case_id=f"{B}x{Sq}x{H}kv{KV}d{D}-{tag}-{dtype}")


def _wkv6_case(B, S, H, K, chunk, dtype) -> ConformanceCase:
    def run_pair():
        import jax
        import jax.numpy as jnp

        from repro.kernels.wkv6.ops import wkv
        from repro.kernels.wkv6.ref import wkv6_ref
        ks = jax.random.split(jax.random.PRNGKey(S + K), 5)
        r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
        k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
        v = jax.random.normal(ks[2], (B, S, H, K)) * 0.5
        w_log = -jnp.exp(
            jax.random.normal(ks[3], (B, S, H, K)) * 0.8 - 2.0)
        u = jax.random.normal(ks[4], (H, K)) * 0.3
        got = wkv(r, k, v, w_log, u, chunk=chunk, interpret=True)
        want = wkv6_ref(r, k, v, w_log, u)
        return got, want

    # chunked kernel vs sequential oracle: accumulation orders differ,
    # so the fp32 policy tolerance is too tight — same bound the
    # dedicated wkv6 tests use.
    return ConformanceCase(
        kernel="wkv6", dtype=dtype, run_pair=run_pair, tol=2e-3,
        case_id=f"{B}x{S}x{H}x{K}-c{chunk}-{dtype}")


def conformance_cases() -> List[ConformanceCase]:
    return [
        _matmul_case(128, 128, 128, 128, 128, 0, "float32"),
        _matmul_case(128, 256, 128, 64, 128, 128, "float32"),
        _matmul_case(128, 128, 256, 128, 128, 0, "bfloat16"),
        _flash_case(1, 128, 128, 4, 2, 64, True, 0, 64, 64, "float32"),
        _flash_case(1, 128, 128, 4, 4, 64, False, 0, 64, 64, "float32"),
        _flash_case(1, 128, 128, 4, 2, 64, True, 32, 64, 64,
                    "bfloat16"),
        _wkv6_case(1, 64, 2, 32, 32, "float32"),
        _wkv6_case(2, 64, 2, 64, 32, "float32"),
    ]
