"""WKV6 (RWKV-6 linear attention with data-dependent per-channel decay)
as a chunked Pallas TPU kernel.

MultiVic mapping: the recurrent state S [K, V] is the scratchpad-
resident working set (it never leaves VMEM between chunks); chunk
inputs stream HBM->VMEM on the static grid schedule.  The grid is
(batch*heads, n_chunks) with the chunk axis sequential ("arbitrary"),
so the VMEM scratch carries S across chunks exactly like a worker
core's accumulator.

Math per chunk (L = chunk length, all fp32 in VMEM):
    cw   = cumsum(w_log)                (inclusive)
    rq   = r * exp(cw - w_log)          (decay-adjusted queries)
    kk   = k * exp(min(-cw, CLAMP))     (decay-adjusted keys)
    A    = tril(rq kk^T, -1); diag via u-bonus
    y    = A v + (r u k) v  + rq S_in
    S'   = exp(cw_L) S_in + (k exp(cw_L - cw))^T v
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_EXP_CLAMP = 30.0


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, s_ref,
            *, nc: int, L: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # [L, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)          # log decay <= 0
    u = u_ref[0].astype(jnp.float32)          # [1, K] bonus

    cw = jnp.cumsum(w, axis=0)                # [L, K]
    total = cw[-1:, :]                        # [1, K]
    e = cw - w                                # exclusive cumsum
    rq = r * jnp.exp(e)                       # exp <= 0: stable

    # Intra-chunk pairwise decay computed DIRECTLY in VMEM — exponent
    # e_t - cw_j <= 0 for t > j, so this is stable for ARBITRARY decay
    # strength (unlike the clamped factorized jnp reference; the [L,L,K]
    # working set is what the scratchpad makes affordable).
    seg = e[:, None, :] - cw[None, :, :]      # [L, L, K]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = (lj < li)[:, :, None]
    P = jnp.where(tri, jnp.exp(seg), 0.0)
    A = jnp.sum(r[:, None, :] * k[None, :, :] * P, axis=-1)  # [L, L]

    diag = jnp.sum(r * u * k, axis=1, keepdims=True)       # [L, 1]
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag * v
    y = y + jax.lax.dot_general(rq, s_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    kdec = k * jnp.exp(total - cw)            # [L, K]
    s_new = s_ref[...] * jnp.exp(total).T + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [K, V]
    s_ref[...] = s_new

    @pl.when(ci == nc - 1)
    def _flush():
        s_out_ref[0] = s_new.astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w_log: jax.Array,
         u: jax.Array, *, chunk: int = 128,
         interpret: bool = False):
    """r,k,v,w_log: [B,S,H,K]; u: [H,K].
    Returns (y [B,S,H,K] in r.dtype, final state [B,H,K,K] fp32)."""
    B, S, H, K = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    fold = lambda a: jnp.moveaxis(a, 1, 2).reshape(B * H, S, K)
    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w_log)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)

    grid = (B * H, nc)
    y, s_fin = pl.pallas_call(
        functools.partial(_kernel, nc=nc, L=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, 1, K), lambda h, c: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, K, K), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, K), r.dtype),
            jax.ShapeDtypeStruct((B * H, K, K), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf)

    y = jnp.moveaxis(y.reshape(B, H, S, K), 1, 2)
    return y, s_fin.reshape(B, H, K, K)
