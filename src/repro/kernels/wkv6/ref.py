"""Oracle for the WKV6 kernel: exact sequential recurrence (fp32).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
"""
import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w_log, u, init_state=None):
    """r,k,v,w_log: [B,S,H,K]; u: [H,K] -> (y [B,S,H,K], S [B,H,K,K])."""
    B, S, H, K = r.shape
    s0 = (jnp.zeros((B, H, K, K), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(S_, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, S_ + u[None, :, :, None] * kv)
        return jnp.exp(wt)[..., None] * S_ + kv, y

    seq = lambda a: jnp.moveaxis(a, 1, 0).astype(jnp.float32)
    final, ys = jax.lax.scan(step, s0, (seq(r), seq(k), seq(v),
                                        seq(w_log)))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), final
