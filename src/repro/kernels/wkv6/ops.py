"""jit'd public wrapper for the WKV6 chunk kernel."""
from __future__ import annotations

from repro.compat import resolve_interpret
from repro.kernels.wkv6.wkv6 import wkv6


def wkv(r, k, v, w_log, u, *, chunk=128, interpret=None):
    return wkv6(r, k, v, w_log, u, chunk=chunk,
                interpret=resolve_interpret(interpret))
