"""jit'd public wrapper for the WKV6 chunk kernel.

Chunk resolution (repro.tuning.resolve_plan): an explicit ``chunk``
argument always wins; otherwise a tuned plan from the persistent plan
cache is used when one exists for this (shape, dtype, environment),
else the shape-safe default.  ``REPRO_AUTOTUNE=0`` disables the cache
consult.
"""
from __future__ import annotations

from typing import Optional

from repro.compat import resolve_interpret
from repro.kernels.wkv6.wkv6 import wkv6


def wkv(r, k, v, w_log, u, *, chunk: Optional[int] = None,
        interpret=None):
    from repro.tuning import WkvProblem, resolve_plan
    B, S, H, K = r.shape
    plan = resolve_plan("wkv6", WkvProblem(B, S, H, K, str(r.dtype)),
                        {"chunk": chunk})
    return wkv6(r, k, v, w_log, u, chunk=plan["chunk"],
                interpret=resolve_interpret(interpret))
