"""jit'd public wrapper for the WKV6 chunk kernel."""
from __future__ import annotations

import jax

from repro.kernels.wkv6.wkv6 import wkv6


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def wkv(r, k, v, w_log, u, *, chunk=128, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return wkv6(r, k, v, w_log, u, chunk=chunk, interpret=interpret)
