"""Deterministic synthetic LM data pipeline.

Determinism is a first-class requirement here: the MultiVic execution
model schedules everything at compile time, and fault-tolerant restart
(runtime/) must resume the EXACT token stream from a step counter alone.
The dataset is therefore a pure function (step, host) -> batch, with a
background prefetch thread layered on top.

At scale each host materializes only its own shard of the global batch
(host-sharded loading); `jax.make_array_from_process_local_data` would
assemble the global array in a multi-process run.  On this single-
process container the local shard IS the global batch.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 1234
    # synthetic structure: token t+1 depends on token t (so a model can
    # actually learn it and the loss decreases in integration tests)
    structure: str = "markov"   # markov | uniform
    num_hosts: int = 1
    host_id: int = 0


class SyntheticLMDataset:
    """Pure-function batches: batch_at(step) is reproducible forever."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.num_hosts == 0
        self.local_batch = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(cfg.seed)
        # fixed random permutation as the markov transition
        self._perm = rng.permutation(cfg.vocab_size)

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, step, c.host_id, 0xD1CE))
        shape = (self.local_batch, c.seq_len + 1)
        if c.structure == "uniform":
            toks = rng.integers(0, c.vocab_size, shape, dtype=np.int32)
        else:
            first = rng.integers(0, c.vocab_size, (self.local_batch, 1),
                                 dtype=np.int32)
            toks = np.empty(shape, np.int32)
            toks[:, 0] = first[:, 0]
            noise = rng.random(shape) < 0.1   # 10% noise tokens
            rand = rng.integers(0, c.vocab_size, shape, dtype=np.int32)
            for t in range(1, shape[1]):
                nxt = self._perm[toks[:, t - 1]]
                toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def make_train_iterator(cfg: DataConfig, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[dict]:
    """Background-thread prefetching iterator starting at start_step
    (checkpoint-restart aware)."""
    ds = SyntheticLMDataset(cfg)
    q: "queue.Queue[Optional[dict]]" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    th = threading.Thread(target=worker, daemon=True)
    th.start()

    class _It:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _It()
