from repro.data.pipeline import (DataConfig, SyntheticLMDataset,
                                 make_train_iterator)

__all__ = ["DataConfig", "SyntheticLMDataset", "make_train_iterator"]
