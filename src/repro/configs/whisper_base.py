"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 —
encoder-decoder with a conv frontend STUB (input_specs() provides
precomputed frame embeddings).  Vocab padded to 51968 so it shards
16-way; padded logits are masked.  [arXiv:2212.04356; unverified]
"""
from repro.configs.base import (AttentionConfig, EncDecConfig, FrontendStub,
                                ModelConfig, register)

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,               # decoder layers; encoder in encdec config
    d_model=512,
    d_ff=2048,
    vocab_size=51_865,
    attention=AttentionConfig(
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        rope_theta=0.0,          # whisper uses learned/sinusoidal positions
    ),
    activation="gelu",
    encdec=EncDecConfig(encoder_layers=6, dec_len_ratio=8,
                        cross_kv_len=1536),
    frontend=FrontendStub(kind="frames"),
))
