"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 with a shared expert on
alternating layers (dense FFN on the others), early-fusion multimodal.
Total params ≈ 400B, ≈17B active.  [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified]
"""
from repro.configs.base import (AttentionConfig, FrontendStub, MoEConfig,
                                ModelConfig, register)

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    d_ff=16384,                  # dense-FFN layers (interleaved)
    vocab_size=202_048,
    attention=AttentionConfig(
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=500_000.0,
    ),
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        expert_ff=8192,
        shared_expert_ff=8192,
        moe_every=2,             # MoE on alternating layers (maverick)
        capacity_factor=1.25,
        group_size=512,
    ),
    activation="swiglu",
    frontend=FrontendStub(kind="patches", num_positions=0),  # early fusion
))
