"""zamba2-7b [hybrid]: 81L d_model=3584 d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 backbone with weight-tied shared attention blocks
(32H MHA, i.e. GQA kv=32) applied periodically.  [arXiv:2411.15242;
unverified]
"""
from repro.configs.base import (AttentionConfig, ModelConfig, SSMConfig,
                                register)

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32_000,
    attention=AttentionConfig(   # the shared attention block
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,            # 3584 / 32
    ),
    ssm=SSMConfig(
        state_dim=64,
        head_dim=64,
        expand=2,
        conv_kernel=4,
        chunk_size=256,
        shared_attn_every=6,     # shared block before every 6th ssm layer
        n_shared_blocks=2,
    ),
    activation="gelu",
))
