"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA with QKV bias.  [arXiv:2407.10671; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151_936,
    attention=AttentionConfig(
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        qkv_bias=True,
    ),
    activation="swiglu",
    tie_embeddings=True,
))
