"""Import every architecture config so the registry is populated."""
# flake8: noqa: F401
from repro.configs import (deepseek_67b, gemma3_12b, llama4_maverick_400b,
                           pixtral_12b, qwen2_0_5b, qwen2_72b,
                           qwen3_moe_235b, rwkv6_1_6b, whisper_base,
                           zamba2_7b)

ALL_ARCH_IDS = (
    "gemma3-12b",
    "qwen2-0.5b",
    "deepseek-67b",
    "qwen2-72b",
    "pixtral-12b",
    "whisper-base",
    "zamba2-7b",
    "llama4-maverick-400b-a17b",
    "qwen3-moe-235b-a22b",
    "rwkv6-1.6b",
)
