"""Configuration system for the repro framework.

ModelConfig is a frozen dataclass covering every assigned architecture
family (dense / GQA / sliding-window / MoE / SSM / RWKV / enc-dec / VLM
and audio stubs).  Shape configs describe the four assigned input-shape
regimes.  Everything is static: the MultiVic execution model requires
input-independent dataflow (paper §3), so every "dynamic" feature
(MoE routing, cache sizes, vocab padding) is frozen at config time.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# helpers


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# attention / layer-pattern descriptors


@dataclass(frozen=True)
class AttentionConfig:
    """Per-model attention settings."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    # sliding-window support: window <= 0 means full (global) attention.
    sliding_window: int = 0
    # pattern of layer kinds, cycled over the depth.  "L" = local
    # (sliding window), "G" = global.  Empty = all global.
    layer_pattern: str = ""
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3 uses 1M for globals
    softmax_scale: Optional[float] = None

    def window_for_layer(self, layer_idx: int) -> int:
        if not self.layer_pattern:
            return self.sliding_window if self.sliding_window > 0 else 0
        kind = self.layer_pattern[layer_idx % len(self.layer_pattern)]
        return self.sliding_window if kind == "L" else 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    """Capacity-factor (static-shape) mixture-of-experts settings.

    Capacity-based dispatch is the static-schedule-compatible MoE: the
    paper requires compile-time-schedulable dataflow, and the capacity
    factor is exactly its "additional assumptions ... during scheduling"
    for dynamic behaviour.
    """

    num_experts: int
    top_k: int
    expert_ff: int
    shared_expert_ff: int = 0  # 0 = no shared expert
    capacity_factor: float = 1.25
    # apply MoE on every `moe_every`-th layer (1 = all layers); other
    # layers use the dense FFN with `dense_ff`.
    moe_every: int = 1
    router_jitter: float = 0.0
    # tokens are grouped for dispatch so the one-hot dispatch tensor
    # stays small; must divide the per-device token count.
    group_size: int = 512

    def capacity(self, group_size: int) -> int:
        cap = int(math.ceil(group_size * self.top_k / self.num_experts
                            * self.capacity_factor))
        return max(4, _round_up(cap, 4))


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings for hybrid/ssm architectures."""

    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256
    # zamba2: a weight-tied attention block applied every N ssm layers
    shared_attn_every: int = 0
    n_shared_blocks: int = 2  # alternating tied blocks (zamba2 uses 2)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 ("Finch") settings: data-dependent decay linear attention."""

    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    chunk_size: int = 256


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder settings (frontend stubbed)."""

    encoder_layers: int = 6
    # ratio of decoder length to the shape's seq_len during training
    dec_len_ratio: int = 8
    cross_kv_len: int = 1536  # encoder memory length seen by decode steps


@dataclass(frozen=True)
class FrontendStub:
    """Modality frontend stub: input_specs() provides precomputed
    frame/patch embeddings; the real conv/ViT stack is out of scope per
    the assignment."""

    kind: str = "none"  # none | patches | frames
    num_positions: int = 0  # e.g. image tokens prepended for VLM


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: FrontendStub = field(default_factory=FrontendStub)
    activation: str = "swiglu"  # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: embeddings * sqrt(d_model)
    # gemma-style sandwich norms (post-norm in addition to pre-norm)
    use_post_norm: bool = False
    logit_softcap: float = 0.0
    vocab_pad_multiple: int = 128
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"
    # --- implementation knobs (semantics-preserving; hillclimb levers) ---
    # pad attention heads up so they divide the model axis; padded heads
    # have zero output-projection rows => mathematically identical.
    pad_heads_to: int = 0
    remat: str = "full"  # full | none
    scan_layers: bool = True
    kernels: str = "reference"  # reference | pallas

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def repeat_pattern_len(self) -> int:
        """Length of the repeating layer unit (for scan stacking)."""
        if self.attention is not None and self.attention.layer_pattern:
            return len(self.attention.layer_pattern)
        return 1

    @property
    def num_repeat_units(self) -> int:
        p = self.repeat_pattern_len
        assert self.num_layers % p == 0 or p == 1, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern {p}")
        return self.num_layers // p if self.num_layers % p == 0 else self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.analysis.flops import param_count  # lazy, avoids cycle
        return param_count(self)


# ---------------------------------------------------------------------------
# input shapes (the four assigned regimes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# training hyper-parameters


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    # distributed-optimization tricks
    grad_compression: str = "none"  # none | int8
    microbatch: int = 0  # 0 = no gradient accumulation


# ---------------------------------------------------------------------------
# registry

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, **overrides) -> ModelConfig:
    """Look up an architecture config by id, optionally overriding
    implementation knobs (not the published architecture fields)."""
    import repro.configs.all_archs  # noqa: F401  (populates registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs():
    import repro.configs.all_archs  # noqa: F401

    return sorted(_REGISTRY)


def supported_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which of the four assigned shapes run for this arch.

    long_500k needs sub-quadratic attention: runs for ssm/hybrid/rwkv and
    sliding-window archs, skipped for pure full-attention archs (see
    DESIGN.md §4).
    """
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    subquadratic = cfg.family in ("ssm", "rwkv", "hybrid") or (
        cfg.attention is not None and cfg.attention.layer_pattern != "")
    if subquadratic:
        shapes.append("long_500k")
    return tuple(shapes)


def reduce_config(cfg: ModelConfig, *, layers: int, d_model: int,
                  vocab: int) -> ModelConfig:
    """CPU-friendly shrink of a registered architecture: same family
    and layer pattern, small dims.  One implementation shared by the
    launchers (launch/train.py --layers/--d-model/--vocab) and the
    serving autotuner (tuning.model), so a plan tuned for a reduced
    arch is tuned for exactly what the launcher serves."""
    kw = dict(num_layers=layers, d_model=d_model, d_ff=d_model * 3,
              vocab_size=vocab, vocab_pad_multiple=64)
    if cfg.attention:
        kw["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4, num_kv_heads=2, head_dim=32)
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            expert_ff=64, group_size=32,
            shared_expert_ff=64 if cfg.moe.shared_expert_ff else 0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk_size=32)
        kw["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4, num_kv_heads=4, head_dim=64)
    if cfg.rwkv:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32,
                                         chunk_size=32)
    if cfg.encdec:
        kw["encdec"] = dataclasses.replace(cfg.encdec, encoder_layers=2)
    return dataclasses.replace(cfg, **kw)
