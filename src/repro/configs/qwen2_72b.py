"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA with QKV bias.  [arXiv:2407.10671; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152_064,
    attention=AttentionConfig(
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        qkv_bias=True,
    ),
    activation="swiglu",
))
