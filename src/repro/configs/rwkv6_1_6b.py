"""rwkv6-1.6b [ssm / linear attention]: 24L d_model=2048 (attn-free)
d_ff=7168 vocab=65536 — "Finch": data-dependent decay linear attention
(WKV6) + token-shift + channel-mix.  [arXiv:2404.05892; unverified]
"""
from repro.configs.base import ModelConfig, RWKVConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    num_layers=24,
    d_model=2048,
    d_ff=7168,
    vocab_size=65_536,
    rwkv=RWKVConfig(
        head_dim=64,             # 32 wkv heads
        decay_lora=64,
        mix_lora=32,
        chunk_size=256,
    ),
    activation="relu_sq",        # rwkv channel-mix uses squared relu
))
