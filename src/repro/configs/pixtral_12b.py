"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo backbone.  The ViT frontend is
a STUB per the assignment: input_specs() provides precomputed patch
embeddings that replace the embeddings at the first `num_positions`
token positions.  [hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.configs.base import (AttentionConfig, FrontendStub, ModelConfig,
                                register)

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131_072,
    attention=AttentionConfig(
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=1_000_000_000.0,
    ),
    activation="swiglu",
    frontend=FrontendStub(kind="patches", num_positions=1024),
))
