from repro.configs.base import (AttentionConfig, EncDecConfig, FrontendStub,
                                MoEConfig, ModelConfig, RWKVConfig, SSMConfig,
                                ShapeConfig, TrainConfig, SHAPES, TRAIN_4K,
                                PREFILL_32K, DECODE_32K, LONG_500K,
                                get_config, list_archs, reduce_config,
                                register, supported_shapes)

__all__ = [
    "AttentionConfig", "EncDecConfig", "FrontendStub", "MoEConfig",
    "ModelConfig", "RWKVConfig", "SSMConfig", "ShapeConfig", "TrainConfig",
    "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "get_config", "list_archs", "reduce_config", "register",
    "supported_shapes",
]
