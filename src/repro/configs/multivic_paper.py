"""The paper's own hardware configurations (Tables 1 and 2).

These describe the MultiVic FPGA design points evaluated in the paper:
the single-core baselines (Small / Medium / Fast Vicuna configs) and the
multi-core variants (Dual / Quad / Octa / Hexadeca).  They are consumed
by repro.core (scheduler / timing model / roofline / resources).

All frequencies are the paper's measured F_max on the VCU128
(Virtex Ultrascale+).  The benchmark clock in Fig. 4 is 100 MHz; the
seconds figures quoted in §5.1 use F_max.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

KIB = 1024
MIB = 1024 * KIB


@dataclass(frozen=True)
class VicunaConfig:
    """One Vicuna vector core (paper Table 1 columns)."""

    vreg_bits: int          # vector register length in bits
    mul_width_bits: int     # multiplier (compute unit) width in bits


@dataclass(frozen=True)
class MultiVicConfig:
    """A full MultiVic design point (paper Tables 1-2)."""

    name: str
    num_worker_cores: int
    vicuna: VicunaConfig
    data_spm_bytes: int          # per worker core
    insn_spm_bytes: int          # per worker core
    fmax_hz: float               # measured on VCU128
    mgmt_insn_spm_bytes: int = 64 * KIB
    mgmt_data_spm_bytes: int = 64 * KIB
    benchmark_clock_hz: float = 100e6   # Fig. 4 measurement clock

    @property
    def is_multicore(self) -> bool:
        return self.num_worker_cores > 1

    @property
    def total_mul_width_bits(self) -> int:
        return self.num_worker_cores * self.vicuna.mul_width_bits

    @property
    def total_data_spm_bytes(self) -> int:
        return self.num_worker_cores * self.data_spm_bytes


# --- Table 1: single-core baselines ---------------------------------------
BASELINE_SMALL = MultiVicConfig(
    "baseline-small", 1, VicunaConfig(128, 32), 1 * MIB, 64 * KIB, 179e6)
BASELINE_MEDIUM = MultiVicConfig(
    "baseline-medium", 1, VicunaConfig(512, 128), 1 * MIB, 64 * KIB, 177e6)
BASELINE_FAST = MultiVicConfig(
    "baseline-fast", 1, VicunaConfig(2048, 1024), 1 * MIB, 64 * KIB, 149e6)

# --- Table 2: multi-core variants ------------------------------------------
DUAL = MultiVicConfig(
    "dual", 2, VicunaConfig(1024, 512), 512 * KIB, 16 * KIB, 168e6)
QUAD = MultiVicConfig(
    "quad", 4, VicunaConfig(512, 256), 256 * KIB, 16 * KIB, 169e6)
OCTA = MultiVicConfig(
    "octa", 8, VicunaConfig(256, 128), 128 * KIB, 16 * KIB, 168e6)
HEXADECA = MultiVicConfig(
    "hexadeca", 16, VicunaConfig(128, 64), 64 * KIB, 16 * KIB, 118e6)

PAPER_CONFIGS: Tuple[MultiVicConfig, ...] = (
    BASELINE_SMALL, BASELINE_MEDIUM, BASELINE_FAST,
    DUAL, QUAD, OCTA, HEXADECA,
)

EVAL_CONFIGS: Tuple[MultiVicConfig, ...] = (
    BASELINE_FAST, DUAL, QUAD, OCTA, HEXADECA)

BY_NAME = {c.name: c for c in PAPER_CONFIGS}

# --- Published measurement anchors (paper §5.1, Fig. 4) --------------------
# Median cycle counts for the 1024^3 matmul benchmark.
PAPER_MEDIAN_CYCLES = {
    "octa": 728_548_804,
    "hexadeca": 548_343_601,
}
# Seconds at F_max quoted in the paper text.
PAPER_SECONDS = {
    "octa": 4.33,
    "hexadeca": 4.65,
}

# Matmul benchmark problem size (paper §4.3)
MATMUL_N = 1024
ELEM_BYTES = 4          # fp32 elements (Vicuna RVV on FP32 words)

# DDR4 on VCU128 via Xilinx MIG: effective bandwidth & worst-case access
# latency assumptions used by the timing model (see core/timing.py).
DDR4_BYTES_PER_CYCLE = 16.0       # effective @ benchmark clock
DDR4_WORST_EXTRA_LATENCY = 64     # cycles, worst-case refresh/row-miss
DDR4_BASE_LATENCY = 32            # cycles, fixed setup per DMA burst
