"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4)
d_ff=1536 (per expert) vocab=151936, MoE 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import (AttentionConfig, MoEConfig, ModelConfig,
                                register)

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    d_ff=12288,                  # unused (all layers MoE); kept for ref
    vocab_size=151_936,
    attention=AttentionConfig(
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
    ),
    moe=MoEConfig(
        num_experts=128,
        top_k=8,
        expert_ff=1536,
        shared_expert_ff=0,
        moe_every=1,             # every layer is MoE
        capacity_factor=1.25,
        group_size=512,
    ),
    activation="swiglu",
))
