"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import AttentionConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    d_ff=15360,
    vocab_size=262_144,
    attention=AttentionConfig(
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,          # gemma3 uses an explicit 256 head_dim
        qk_norm=True,
        sliding_window=1024,
        layer_pattern="LLLLLG",  # 5 local : 1 global
        rope_theta=10_000.0,
        rope_theta_global=1_000_000.0,
    ),
    activation="geglu",
    use_post_norm=True,
    tie_embeddings=True,
    scale_embeddings=True,
))
