"""Unit tests for the analysis layer: HLO collective parser, analytic
FLOPs/bytes models, roofline term math, piece composition."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.bytes_model import analytic_bytes
from repro.analysis.flops import (active_param_count, model_flops,
                                  param_count)
from repro.analysis.hlo import (collective_stats, shape_bytes,
                                summarize_compiled,
                                total_collective_bytes)
from repro.analysis.roofline import (compose_pieces, roofline_terms,
                                     PEAK_FLOPS)
from repro.configs import SHAPES, get_config


def test_shape_bytes_parser():
    assert shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert shape_bytes("f32[4096]") == 4096 * 4
    assert shape_bytes("(f32[2,2], s8[16])") == 16 + 16
    assert shape_bytes("pred[]") == 1


def test_collective_stats_from_text():
    txt = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[64,32]{1,0} all-gather(%y), dimensions={0}
  %aa = f32[8,8]{1,0} all-to-all(%z), dimensions={0}
  %nothing = f32[4]{0} add(%a, %b)
"""
    s = collective_stats(txt)
    assert s["all-reduce"]["count"] == 1
    assert s["all-reduce"]["bytes"] == 4096
    assert s["all-gather"]["bytes"] == 64 * 32 * 2
    assert "add" not in s
    assert total_collective_bytes(s) == 4096 + 4096 + 256


def test_summarize_compiled_real_program():
    c = jax.jit(lambda x: (x @ x).sum()).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    rec = summarize_compiled(c)
    assert rec["flops"] >= 2 * 64 ** 3 * 0.9
    assert "memory" in rec and rec["memory"]["argument_bytes"] == 64*64*4


def test_param_counts_sane():
    cfg = get_config("qwen2-72b")
    n = param_count(cfg)
    assert 70e9 < n < 85e9, n            # ~72B + embeddings
    assert active_param_count(cfg) == n  # dense: all params active
    moe = get_config("qwen3-moe-235b-a22b")
    n_tot, n_act = param_count(moe), active_param_count(moe)
    assert 200e9 < n_tot < 260e9, n_tot
    assert 15e9 < n_act < 30e9, n_act    # ~22B active


def test_llama4_param_budget():
    cfg = get_config("llama4-maverick-400b-a17b")
    n_tot, n_act = param_count(cfg), active_param_count(cfg)
    assert 360e9 < n_tot < 440e9, n_tot   # ~400B as published
    assert 10e9 < n_act < 25e9, n_act     # ~17B active


def test_model_flops_regimes():
    cfg = get_config("qwen2-0.5b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    assert tr == pytest.approx(
        6 * active_param_count(cfg) * 256 * 4096)
    assert pf == pytest.approx(
        2 * active_param_count(cfg) * 32 * 32768)
    assert de == pytest.approx(2 * active_param_count(cfg) * 128)


def test_roofline_terms_math():
    t = roofline_terms(PEAK_FLOPS, 819e9, 50e9)   # 1s each
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["roofline_fraction"] == pytest.approx(1.0)
    t = roofline_terms(PEAK_FLOPS, 2 * 819e9, 50e9)
    assert t["dominant"] == "memory"
    assert t["roofline_fraction"] == pytest.approx(0.5)


def test_compose_pieces_multiplies():
    recs = [{"multiplier": 10, "flops": 2.0, "bytes_accessed": 3.0,
             "collective_bytes": 1.0},
            {"multiplier": 1, "flops": 5.0, "bytes_accessed": 7.0,
             "collective_bytes": 0.0}]
    tot = compose_pieces(recs)
    assert tot == {"flops": 25.0, "bytes_accessed": 37.0,
                   "collective_bytes": 10.0}


def test_analytic_bytes_regimes():
    cfg = get_config("qwen2-72b")
    tr = analytic_bytes(cfg, SHAPES["train_4k"])
    de = analytic_bytes(cfg, SHAPES["decode_32k"])
    de_tp = analytic_bytes(cfg, SHAPES["decode_32k"], weight_shards=16)
    assert tr["total"] > de["total"]          # train moves more
    # serving TP reads a 16x bigger weight shard per step
    assert de_tp["weights"] == pytest.approx(16 * de["weights"])
    # decode is weight/cache-dominated
    assert (de["weights"] + de["kv_cache_read"]) / de["total"] > 0.5