"""Tier-1 enforcement of the compat seam: no module outside
src/repro/compat.py may reference version-sensitive JAX symbols
directly (scripts/check_compat_imports.py holds the patterns)."""
import importlib.util
import pathlib

_SCRIPT = (pathlib.Path(__file__).resolve().parent.parent / "scripts"
           / "check_compat_imports.py")


def _load_linter():
    spec = importlib.util.spec_from_file_location(
        "check_compat_imports", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_no_direct_version_sensitive_imports():
    linter = _load_linter()
    violations = linter.find_violations()
    msg = "\n".join(f"{rel}:{line}: {why}\n    {src}"
                    for rel, line, why, src in violations)
    assert not violations, f"compat seam violations:\n{msg}"


def test_linter_catches_seeded_violation(tmp_path):
    """The lint actually fires: a synthetic tree with a raw compiler-
    params reference must be flagged."""
    linter = _load_linter()
    bad = tmp_path / "src" / "repro" / "kernels"
    bad.mkdir(parents=True)
    attr = "TPU" + "Compiler" + "Params"
    (bad / "rogue.py").write_text(
        f"from jax.experimental.pallas import tpu\np = tpu.{attr}()\n")
    violations = linter.find_violations(tmp_path)
    assert len(violations) == 1
    assert violations[0][0] == "src/repro/kernels/rogue.py"


def test_lint_scope_covers_benchmarks_and_obs():
    """The seam guard must watch every directory that may grow JAX
    code — in particular benchmarks/ and the src/repro/obs layer."""
    linter = _load_linter()
    assert set(linter.SCAN_DIRS) >= {"src", "tests", "scripts",
                                     "benchmarks", "examples"}


def test_linter_fires_in_benchmarks_and_obs(tmp_path):
    """Seeded violations in benchmarks/ and src/repro/obs/ are both
    caught — the new directories are inside the lint scope, so the
    compat seam stays the only version-sensitive module."""
    linter = _load_linter()
    attr = "TPU" + "Compiler" + "Params"
    bench = tmp_path / "benchmarks"
    bench.mkdir(parents=True)
    (bench / "rogue_bench.py").write_text(
        f"import jax.experimental.pallas.tpu as t\np = t.{attr}()\n")
    obs = tmp_path / "src" / "repro" / "obs"
    obs.mkdir(parents=True)
    (obs / "rogue_obs.py").write_text(
        "from jax.experimental.shard_map import shard" + "_map\n")
    violations = linter.find_violations(tmp_path)
    assert {v[0] for v in violations} == {
        "benchmarks/rogue_bench.py", "src/repro/obs/rogue_obs.py"}


def test_linter_fires_in_resilience(tmp_path):
    """src/repro/resilience/ is inside the lint scope: the chaos
    harness and degradation policies are accelerator-free by design,
    so any version-sensitive JAX symbol appearing there is doubly
    wrong."""
    linter = _load_linter()
    res = tmp_path / "src" / "repro" / "resilience"
    res.mkdir(parents=True)
    (res / "rogue_chaos.py").write_text(
        "from jax.experimental.shard_map import shard" + "_map\n")
    violations = linter.find_violations(tmp_path)
    assert {v[0] for v in violations} == {
        "src/repro/resilience/rogue_chaos.py"}


def test_linter_fires_in_tuning(tmp_path):
    """src/repro/tuning/ is inside the lint scope: the autotuner calls
    kernels but must never touch version-sensitive JAX symbols
    directly (plan resolution has to work without importing jax)."""
    linter = _load_linter()
    tuning = tmp_path / "src" / "repro" / "tuning"
    tuning.mkdir(parents=True)
    (tuning / "rogue_tuner.py").write_text(
        "from jax.sharding import Axis" + "Type\n")
    violations = linter.find_violations(tmp_path)
    assert {v[0] for v in violations} == {
        "src/repro/tuning/rogue_tuner.py"}
