"""Chaos soak: the recovery paths must *compose*, not just exist.

A trainer run under a multi-fault plan — NaN-poisoned step, transient
checkpoint-I/O errors, preemption mid-save, then the newest checkpoint
corrupted on disk before relaunch — must resume and reach final
parameters BIT-EXACT equal to an undisturbed reference run.  This is
the paper's predictability doctrine applied to failures: every fault
is an anticipated scenario with a deterministic recovery path, so the
trajectory is invariant under the whole plan.
"""
import warnings

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig
from repro.models.lm import RunOptions
from repro.obs import TraceRecorder, to_chrome_trace
from repro.resilience import Fault, FaultPlan, apply_offline_fault
from repro.runtime.trainer import NonFiniteLossError, Trainer


def _trainer(tmp=None, steps=12, **kw):
    cfg = tiny_cfg("qwen2-0.5b", num_layers=1, d_model=64, d_ff=128,
                   vocab_size=64, vocab_pad_multiple=64)
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=2,
                       total_steps=steps, seed=0)
    dcfg = DataConfig(vocab_size=64, global_batch=4, seq_len=16)
    opts = RunOptions(chunk_q=16, chunk_kv=16, loss_chunk=16,
                      remat=False)
    return Trainer(cfg, tcfg, dcfg, ckpt_dir=tmp, ckpt_every=3,
                   opts=opts, log_every=0, **kw)


def _bits(params):
    return [np.asarray(l).tobytes() for l in jax.tree.leaves(params)]


def test_nan_injection_is_invisible_in_final_params(tmp_path):
    """A transient NaN step is retried, not skipped: the poisoned
    update is discarded in-step and the retry sees the same batch, so
    the final parameters carry no imprint of the fault."""
    ref = _trainer(steps=6)
    ref.run(6)

    rec = TraceRecorder()
    plan = FaultPlan([Fault(2, "nan_loss")])
    tr = _trainer(steps=6, chaos=plan, trace=rec)
    tr.run(6)

    assert tr.nonfinite_steps == [2]
    assert _bits(tr.final_state.params) == _bits(ref.final_state.params)
    names = [i.name for i in rec.instants]
    assert "chaos_nan_loss" in names and "nonfinite_skipped" in names


def test_persistent_nonfinite_aborts(tmp_path):
    plan = FaultPlan([Fault(1, "nan_loss")])
    tr = _trainer(steps=6, chaos=plan, max_nonfinite=1)
    with pytest.raises(NonFiniteLossError):
        tr.run(6)


def test_chaos_soak_resumes_bit_exact(tmp_path):
    N = 12
    # ---- undisturbed reference ------------------------------------
    ref = _trainer(str(tmp_path / "ref"), N)
    ref.run(N)

    # ---- phase 1: NaN step + transient ckpt I/O + preempt mid-save
    rec1 = TraceRecorder()
    plan = FaultPlan([
        Fault(4, "nan_loss"),
        Fault(5, "io_error", count=2),   # hits the step-6 bg save
        Fault(7, "preempt"),
    ], seed=3, trace=rec1)
    tr1 = _trainer(str(tmp_path / "chaos"), N, trace=rec1, chaos=plan)
    tr1.run(N)
    assert plan.done()
    assert tr1.final_state.step == 8     # preempted, exited cleanly
    assert tr1.nonfinite_steps == [4]

    names1 = [i.name for i in rec1.instants]
    for expected in ("chaos_nan_loss", "chaos_io_error",
                     "chaos_preempt", "nonfinite_skipped", "io_retry",
                     "ckpt_saved"):
        assert expected in names1, (expected, names1)

    # ---- crash window: the newest checkpoint is damaged on disk ---
    rec2 = TraceRecorder()
    hit = apply_offline_fault(
        Fault(8, "ckpt_corrupt", mode="truncate"),
        ckpt_dir=tmp_path / "chaos", trace=rec2)
    assert hit == 8

    # ---- phase 2: relaunch; restore must fall back to intact step 6
    tr2 = _trainer(str(tmp_path / "chaos"), N, trace=rec2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        tr2.run(N)
    assert tr2.final_state.step == N

    names2 = [i.name for i in rec2.instants]
    assert "chaos_ckpt_corrupt" in names2
    assert "ckpt_fallback" in names2     # step 8 rejected
    assert "ckpt_restored" in names2     # step 6 accepted
    restored = [i for i in rec2.instants if i.name == "ckpt_restored"]
    assert dict(restored[0].args)["step"] == 6

    # ---- the whole composition is invisible: bit-exact equality ---
    assert _bits(tr2.final_state.params) == _bits(ref.final_state.params)
    assert _bits(tr2.final_state.opt_state) == _bits(
        ref.final_state.opt_state)

    # every fault and recovery survives export to the Chrome trace
    events = {e["name"] for rec in (rec1, rec2)
              for e in to_chrome_trace(rec)["traceEvents"]
              if e.get("ph") == "i"}
    assert {"chaos_nan_loss", "chaos_io_error", "chaos_preempt",
            "chaos_ckpt_corrupt", "nonfinite_skipped", "io_retry",
            "ckpt_saved", "ckpt_fallback",
            "ckpt_restored"} <= events
