"""Property tests on model components (hypothesis where useful):
RoPE shift structure, sliding-window mask semantics, spec/cache
consistency across every assigned architecture, SSD chunk invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import TINY_LAYERS, tiny_cfg
from repro.configs.all_archs import ALL_ARCH_IDS
from repro.models import cache_spec, model_spec
from repro.models.attention import sdpa
from repro.models.common import apply_rope
from repro.models.spec import is_par


# ------------------------------------------------------------------ rope

def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 64))
    pos = jnp.arange(8)
    y = apply_rope(x, pos, 10_000.0)
    nx = jnp.linalg.norm(x, axis=-1)
    ny = jnp.linalg.norm(y, axis=-1)
    assert float(jnp.max(jnp.abs(nx - ny))) < 1e-4


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))

    def score(i, j):
        qr = apply_rope(q, jnp.array([i]), 10_000.0)
        kr = apply_rope(k, jnp.array([j]), 10_000.0)
        return float(jnp.sum(qr * kr))

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(7, 0) == pytest.approx(score(57, 50), rel=1e-4)


# ------------------------------------------------------------ attn masks

@given(w=st.sampled_from([64, 128, 1 << 20]))
@settings(max_examples=6, deadline=None)
def test_window_geq_seq_equals_full(w):
    S = 64
    ks = jax.random.split(jax.random.PRNGKey(w), 3)
    q = jax.random.normal(ks[0], (1, S, 4, 32))
    k = jax.random.normal(ks[1], (1, S, 2, 32))
    v = jax.random.normal(ks[2], (1, S, 2, 32))
    pos = jnp.arange(S)
    full = sdpa(q, k, v, pos, pos, causal=True, window=0, scale=0.2,
                chunk_q=0, chunk_kv=0)
    win = sdpa(q, k, v, pos, pos, causal=True, window=w, scale=0.2,
               chunk_q=0, chunk_kv=0)
    if w >= S:
        assert float(jnp.max(jnp.abs(full - win))) < 1e-5
    else:
        assert float(jnp.max(jnp.abs(full - win))) > 1e-4


def test_chunked_equals_single_block():
    S = 64
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (2, S, 4, 32))
    k = jax.random.normal(ks[1], (2, S, 2, 32))
    v = jax.random.normal(ks[2], (2, S, 2, 32))
    pos = jnp.arange(S)
    a = sdpa(q, k, v, pos, pos, causal=True, window=48, scale=0.18,
             chunk_q=0, chunk_kv=0)
    b = sdpa(q, k, v, pos, pos, causal=True, window=48, scale=0.18,
             chunk_q=16, chunk_kv=16)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


# ------------------------------------------- spec consistency, all archs

@pytest.mark.parametrize("arch", ALL_ARCH_IDS)
def test_model_and_cache_specs_consistent(arch):
    cfg = tiny_cfg(arch, num_layers=TINY_LAYERS[arch])
    spec = model_spec(cfg)
    leaves = jax.tree.leaves(spec, is_leaf=is_par)
    assert leaves, arch
    for p in leaves:
        assert len(p.shape) == len(p.axes)
        assert all(d > 0 for d in p.shape)
    cspec = cache_spec(cfg, batch=2, cache_len=32)
    for p in jax.tree.leaves(cspec, is_leaf=is_par):
        assert p.axes[0] == "stack"          # scan-stacked
        assert "batch" in p.axes             # every cache leaf is per-seq


@pytest.mark.parametrize("arch", ALL_ARCH_IDS)
def test_layer_counts_match_config(arch):
    from repro.models.blocks import build_stages
    cfg = tiny_cfg(arch, num_layers=TINY_LAYERS[arch])
    n = sum(st_.n_units * st_.unit_len for st_ in build_stages(cfg))
    assert n == cfg.num_layers, (arch, n, cfg.num_layers)


# ------------------------------------------------------------- ssd chunks

def test_ssd_chunk_size_invariance():
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 1, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    a = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    y16, s16 = ssd_chunked(x, a, Bm, Cm, 16)
    y64, s64 = ssd_chunked(x, a, Bm, Cm, 64)
    assert float(jnp.max(jnp.abs(y16 - y64))) < 1e-4
    assert float(jnp.max(jnp.abs(s16 - s64))) < 1e-4