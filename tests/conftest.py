import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Hermeticity: never let the suite read (or write) a developer's real
# tuning plan cache — kernel wrappers would silently pick up tuned
# block plans and change what the conformance cases execute.
# tests/test_tuning.py re-enables autotuning per-test with a tmp cache.
os.environ.setdefault("REPRO_AUTOTUNE", "0")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

from repro.configs import get_config  # noqa: E402
from repro.models.lm import RunOptions  # noqa: E402

TINY_OPTS = RunOptions(chunk_q=16, chunk_kv=16, loss_chunk=16, remat=False)

# Shared kernel tolerance policy: one place decides how close a Pallas
# kernel must track its ref.py oracle (relative max-abs error, scaled
# by the oracle's magnitude).  Used by tests/kernel_conformance.py for
# every registered kernel; per-case overrides exist only for kernels
# whose oracle accumulates in a different order (see kernels/__init__).
KERNEL_TOLERANCES = {
    "float32": 1e-5,
    "bfloat16": 3e-2,
}


def assert_kernel_close(got, want, dtype: str, tol: float = None):
    tol = tol if tol is not None else KERNEL_TOLERANCES[dtype]
    got_leaves = jax.tree.leaves(got)
    want_leaves = jax.tree.leaves(want)
    assert len(got_leaves) == len(want_leaves), \
        (len(got_leaves), len(want_leaves))
    for g, w in zip(got_leaves, want_leaves):
        g = jnp.asarray(g, jnp.float32)
        w = jnp.asarray(w, jnp.float32)
        assert g.shape == w.shape, (g.shape, w.shape)
        scale = float(jnp.max(jnp.abs(w))) + 1e-9
        err = float(jnp.max(jnp.abs(g - w))) / scale
        assert err < tol, f"rel err {err:.2e} >= {tol:.0e} ({dtype})"


def tiny_cfg(name: str, **kw):
    """Reduced-config instance of an assigned architecture (same family,
    small dims) — used by the per-arch smoke tests."""
    cfg = get_config(name)
    base = dict(d_model=128, d_ff=256, vocab_size=512,
                vocab_pad_multiple=64)
    if cfg.attention:
        base["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4, num_kv_heads=2, head_dim=32)
    if cfg.moe:
        base["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            expert_ff=64, group_size=32, capacity_factor=2.0,
            shared_expert_ff=(64 if cfg.moe.shared_expert_ff else 0))
    if cfg.ssm:
        base["ssm"] = dataclasses.replace(cfg.ssm, chunk_size=16)
        base["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4, num_kv_heads=4, head_dim=64)
    if cfg.rwkv:
        base["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32,
                                           chunk_size=16)
    if cfg.encdec:
        base["encdec"] = dataclasses.replace(
            cfg.encdec, encoder_layers=2, cross_kv_len=32)
    base.update(kw)
    return dataclasses.replace(cfg, **base)


TINY_LAYERS = {
    "gemma3-12b": 6,            # one 5:1 local:global pattern unit
    "qwen2-0.5b": 2,
    "deepseek-67b": 2,
    "qwen2-72b": 2,
    "pixtral-12b": 2,
    "whisper-base": 2,
    "zamba2-7b": 15,            # 2 units of [shared+5] + 3-layer tail
    "llama4-maverick-400b-a17b": 4,
    "qwen3-moe-235b-a22b": 2,
    "rwkv6-1.6b": 2,
}
