import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

from repro.configs import get_config  # noqa: E402
from repro.models.lm import RunOptions  # noqa: E402

TINY_OPTS = RunOptions(chunk_q=16, chunk_kv=16, loss_chunk=16, remat=False)


def tiny_cfg(name: str, **kw):
    """Reduced-config instance of an assigned architecture (same family,
    small dims) — used by the per-arch smoke tests."""
    cfg = get_config(name)
    base = dict(d_model=128, d_ff=256, vocab_size=512,
                vocab_pad_multiple=64)
    if cfg.attention:
        base["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4, num_kv_heads=2, head_dim=32)
    if cfg.moe:
        base["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            expert_ff=64, group_size=32, capacity_factor=2.0,
            shared_expert_ff=(64 if cfg.moe.shared_expert_ff else 0))
    if cfg.ssm:
        base["ssm"] = dataclasses.replace(cfg.ssm, chunk_size=16)
        base["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4, num_kv_heads=4, head_dim=64)
    if cfg.rwkv:
        base["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32,
                                           chunk_size=16)
    if cfg.encdec:
        base["encdec"] = dataclasses.replace(
            cfg.encdec, encoder_layers=2, cross_kv_len=32)
    base.update(kw)
    return dataclasses.replace(cfg, **base)


TINY_LAYERS = {
    "gemma3-12b": 6,            # one 5:1 local:global pattern unit
    "qwen2-0.5b": 2,
    "deepseek-67b": 2,
    "qwen2-72b": 2,
    "pixtral-12b": 2,
    "whisper-base": 2,
    "zamba2-7b": 15,            # 2 units of [shared+5] + 3-layer tail
    "llama4-maverick-400b-a17b": 4,
    "qwen3-moe-235b-a22b": 2,
    "rwkv6-1.6b": 2,
}
