"""Jitter-aware autotuner (repro.tuning): candidates, cost model,
plan cache, tune() round-trip, and wrapper integration.

Tier-1 runs with REPRO_AUTOTUNE=0 (conftest) so kernel wrappers never
consult a developer's cache; tests that exercise the cache path
re-enable it with monkeypatch + a tmp REPRO_PLAN_CACHE and reset the
process-wide cache singleton around themselves.
"""
import json

import pytest

from repro import tuning
from repro.obs import TraceRecorder
from repro.tuning import (DEFAULT_PROBLEMS, AttentionProblem,
                          MatmulProblem, PlanCache, WkvProblem,
                          analytic_cost_s, cache_key, defaults_for,
                          enumerate_candidates, feasibility,
                          measure_callable, measurement_count,
                          parse_problem, plan_sig, resolve_plan,
                          select_plan, tune, vmem_need)
from repro.tuning.plan_cache import CACHE_SCHEMA_VERSION

MM = MatmulProblem(512, 512, 512)
ATTN = AttentionProblem(1, 256, 256, 4, 2, 64)
WKV = WkvProblem(1, 256, 2, 64)


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Fresh cache file + re-enabled autotuning + clean singleton."""
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    tuning.reset()
    yield path
    tuning.reset()


# ------------------------------------------------------------ candidates

def test_defaults_reproduce_bench_plans():
    assert defaults_for("spm_matmul", MM) == {"bm": 256, "bn": 256,
                                              "bk": 0}
    assert defaults_for("flash_attention", ATTN) == {"bq": 256,
                                                     "bk": 256}
    assert defaults_for("wkv6", WKV) == {"chunk": 128}


def test_defaults_are_shape_safe():
    # odd dims must still produce dividing blocks
    p = MatmulProblem(96, 96, 96)
    d = defaults_for("spm_matmul", p)
    assert p.m % d["bm"] == 0 and p.n % d["bn"] == 0
    a = AttentionProblem(1, 48, 48, 2, 2, 32)
    da = defaults_for("flash_attention", a)
    assert a.seq_q % da["bq"] == 0 and a.seq_k % da["bk"] == 0
    w = WkvProblem(1, 48, 2, 32)
    assert w.seq % defaults_for("wkv6", w)["chunk"] == 0


@pytest.mark.parametrize("kernel,problem", [
    ("spm_matmul", MM), ("flash_attention", ATTN), ("wkv6", WKV)])
def test_candidates_divide_and_include_default(kernel, problem):
    cands = enumerate_candidates(kernel, problem)
    assert defaults_for(kernel, problem) in cands
    for plan in cands:
        if kernel == "spm_matmul":
            assert problem.m % plan["bm"] == 0
            assert problem.n % plan["bn"] == 0
            assert plan["bk"] == 0 or problem.k % plan["bk"] == 0
        elif kernel == "flash_attention":
            assert problem.seq_q % plan["bq"] == 0
            assert problem.seq_k % plan["bk"] == 0
        else:
            assert problem.seq % plan["chunk"] == 0


def test_parse_problem_round_trip():
    assert parse_problem("spm_matmul", "512x512x512") == MM
    assert parse_problem("flash_attention", "1x256x4x2x64") == ATTN
    assert parse_problem("wkv6", "1x256x2x64") == WKV
    with pytest.raises(ValueError):
        parse_problem("spm_matmul", "512x512")


# ------------------------------------------------------------ cost model

def test_vmem_feasibility_rejects_oversized_plans():
    huge = MatmulProblem(16384, 16384, 16384)
    fat = {"bm": 16384, "bn": 16384, "bk": 0}
    assert not feasibility("spm_matmul", huge, fat).fits
    thin = {"bm": 128, "bn": 128, "bk": 512}
    assert feasibility("spm_matmul", huge, thin).fits
    assert vmem_need("spm_matmul", huge, fat) \
        > vmem_need("spm_matmul", huge, thin)


def test_analytic_cost_prefers_coarser_blocking():
    # finer blocks re-stream A more often AND run a longer grid, so
    # the model must rank them strictly worse on the resident-B path
    coarse = analytic_cost_s("spm_matmul", MM,
                             {"bm": 512, "bn": 512, "bk": 0})
    fine = analytic_cost_s("spm_matmul", MM,
                           {"bm": 128, "bn": 128, "bk": 0})
    assert 0 < coarse < fine


def test_cost_positive_for_all_bench_candidates():
    for kernel, problem in DEFAULT_PROBLEMS.items():
        for plan in enumerate_candidates(kernel, problem):
            assert analytic_cost_s(kernel, problem, plan) > 0


# ------------------------------------------------------- jitter selection

def _stats(samples):
    from repro.obs import jitter_stats
    return jitter_stats(samples)


def test_select_plan_prefers_low_p99():
    fast = ({"bm": 1}, _stats([100.0, 101.0, 102.0]))
    slow = ({"bm": 2}, _stats([200.0, 201.0, 202.0]))
    plan, _ = select_plan([slow, fast])
    assert plan == {"bm": 1}


def test_select_plan_cov_tie_break():
    # within 5% p99 tie window: steadier plan wins despite higher mean
    steady = ({"bm": 1}, _stats([103.0, 103.0, 103.0, 103.0]))
    jittery = ({"bm": 2}, _stats([80.0, 100.0, 100.0, 104.0]))
    plan, _ = select_plan([steady, jittery], tie_rel=0.05)
    assert plan == {"bm": 1}


def test_measure_callable_records_spans():
    rec = TraceRecorder()
    stats = measure_callable(lambda: None, reps=3, warmup=1, trace=rec)
    assert stats.n == 3
    assert measurement_count(rec) == 3


# -------------------------------------------------------------- plan cache

def test_plan_cache_round_trip(tmp_path):
    path = tmp_path / "c.json"
    c1 = PlanCache(str(path))
    c1.put("k|sig|env", {"bm": 128}, kernel="spm_matmul")
    c1.save()
    c2 = PlanCache(str(path))
    assert c2.get("k|sig|env") == {"bm": 128}
    assert c2.hits == 1
    entry = c2.entry("k|sig|env")
    assert entry["kernel"] == "spm_matmul"
    assert "tuned_at" in entry and "env" in entry
    assert c2.get("missing") is None and c2.misses == 1


def test_corrupt_cache_degrades_to_defaults(tmp_path):
    path = tmp_path / "c.json"
    path.write_text("{not json at all", encoding="utf-8")
    cache = PlanCache(str(path))
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert cache.get("anything") is None
    # and a wrong-schema file likewise
    path2 = tmp_path / "c2.json"
    path2.write_text(json.dumps({"schema_version": 999, "plans": {}}),
                     encoding="utf-8")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert PlanCache(str(path2)).get("x") is None


def test_mis_shaped_entry_warns_and_misses(tmp_path):
    path = tmp_path / "c.json"
    path.write_text(json.dumps({
        "schema_version": CACHE_SCHEMA_VERSION,
        "plans": {"bad": {"plan": {"bm": "big"}}}}), encoding="utf-8")
    cache = PlanCache(str(path))
    with pytest.warns(RuntimeWarning, match="mis-shaped"):
        assert cache.get("bad") is None
    assert cache.misses == 1


# ---------------------------------------------------------- tune round-trip

def test_tune_round_trip_zero_measurements_on_warm_cache(tmp_cache):
    problem = MatmulProblem(64, 64, 64)
    trace1 = TraceRecorder()
    r1 = tune("spm_matmul", problem, reps=2, warmup=1,
              interpret=True, trace=trace1)
    assert r1.source == "measured"
    assert r1.measured == measurement_count(trace1) > 0
    assert tmp_cache.exists()

    # fresh cache object (same file): zero measurements, same plan
    trace2 = TraceRecorder()
    r2 = tune("spm_matmul", problem, cache=PlanCache(str(tmp_cache)),
              reps=2, warmup=1, interpret=True, trace=trace2)
    assert r2.source == "cache"
    assert r2.measured == 0
    assert measurement_count(trace2) == 0
    assert r2.plan == r1.plan


def test_tune_force_remeasures(tmp_cache):
    problem = WkvProblem(1, 64, 1, 32)
    tune("wkv6", problem, reps=1, interpret=True)
    trace = TraceRecorder()
    r = tune("wkv6", problem, reps=1, interpret=True, force=True,
             trace=trace)
    assert r.source == "measured"
    assert measurement_count(trace) > 0


# --------------------------------------------------------- plan resolution

def test_resolve_plan_precedence(tmp_cache):
    problem = MatmulProblem(512, 512, 512)
    # no cache entry: defaults
    assert resolve_plan("spm_matmul", problem,
                        {"bm": None, "bn": None, "bk": None}) \
        == {"bm": 256, "bn": 256, "bk": 0}
    # cached plan overlays defaults
    cache = tuning.active_cache()
    cache.put(cache_key("spm_matmul", problem),
              {"bm": 512, "bn": 512, "bk": 0})
    assert resolve_plan("spm_matmul", problem,
                        {"bm": None, "bn": None, "bk": None}) \
        == {"bm": 512, "bn": 512, "bk": 0}
    # explicit args beat the cache, merging with it per-param
    assert resolve_plan("spm_matmul", problem,
                        {"bm": 128, "bn": None, "bk": None}) \
        == {"bm": 128, "bn": 512, "bk": 0}


def test_resolve_plan_disabled_ignores_cache(tmp_cache, monkeypatch):
    problem = MatmulProblem(512, 512, 512)
    tuning.active_cache().put(cache_key("spm_matmul", problem),
                              {"bm": 512, "bn": 512, "bk": 0})
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert resolve_plan("spm_matmul", problem,
                        {"bm": None, "bn": None, "bk": None}) \
        == {"bm": 256, "bn": 256, "bk": 0}


def test_wrapper_consults_cache(tmp_cache):
    """End-to-end: a tuned plan in the cache changes nothing about the
    result but is actually consulted by the public wrapper."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.spm_matmul.ops import matmul
    from repro.kernels.spm_matmul.ref import matmul_ref
    problem = MatmulProblem(128, 128, 128)
    tuning.active_cache().put(cache_key("spm_matmul", problem),
                              {"bm": 64, "bn": 64, "bk": 0})
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (128, 128))
    b = jax.random.normal(kb, (128, 128))
    hits0 = tuning.active_cache().hits
    got = matmul(a, b, interpret=True)
    assert tuning.active_cache().hits == hits0 + 1
    assert jnp.allclose(got, matmul_ref(a, b), atol=1e-4)


# --------------------------------------------------------------- registry

def test_registry_tune_specs_and_conformance_agree():
    from repro.kernels import KERNEL_REGISTRY, conformance_cases
    from repro.tuning.candidates import TUNE_SPECS
    assert set(KERNEL_REGISTRY) == set(TUNE_SPECS) \
        == set(DEFAULT_PROBLEMS) \
        == {c.kernel for c in conformance_cases()}
    for name, entry in KERNEL_REGISTRY.items():
        assert set(entry.plan_params) \
            == set(TUNE_SPECS[name].param_names)
        # defaults emit exactly the registered params
        assert set(defaults_for(name, DEFAULT_PROBLEMS[name])) \
            == set(entry.plan_params)


def test_plan_sig_is_canonical():
    assert plan_sig({"bn": 512, "bm": 256, "bk": 0}) \
        == "bk0.bm256.bn512"


# ------------------------------------------------------------------- slow

@pytest.mark.slow
def test_exhaustive_candidate_sweep_measures_consistently(tmp_cache):
    """Every feasible candidate on the bench shapes runs and returns
    finite stats (not tier-1: measures dozens of plans)."""
    from repro.tuning import make_runner
    for kernel, problem in DEFAULT_PROBLEMS.items():
        for plan in enumerate_candidates(kernel, problem):
            if not feasibility(kernel, problem, plan).fits:
                continue
            stats = measure_callable(
                make_runner(kernel, problem, plan, interpret=True),
                reps=2, warmup=1)
            assert stats.mean > 0 and stats.p99 > 0
