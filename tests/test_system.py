"""End-to-end behaviour tests for the paper's system: the static
schedule pipeline (schedule -> simulate -> WCET) and its TPU mapping,
exercised through the public API."""
import jax
import jax.numpy as jnp

from repro.core import (MatmulProblem, build_matmul_schedule, run_many,
                        schedule_totals, simulate, wcet)
from repro.configs.multivic_paper import OCTA


def test_end_to_end_schedule_pipeline():
    prob = MatmulProblem(256, 256, 256)
    sched = build_matmul_schedule(OCTA, prob)
    totals = schedule_totals(sched)
    assert totals["macs"] == 256 ** 3
    stats = run_many(sched, OCTA, n_runs=5)
    bound = wcet(sched, OCTA)
    assert stats["max"] <= bound
    assert stats["std"] < 1e-3 * stats["median"]   # time-predictable


def test_kernel_agrees_with_simulated_workload():
    """The Pallas kernel computes the same problem the schedule
    describes — numerics via ref, work accounting via schedule."""
    from repro.kernels.spm_matmul.ops import matmul
    from repro.kernels.spm_matmul.ref import matmul_ref
    n = 256
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    got = matmul(a, b, bm=128, bn=128)
    want = matmul_ref(a, b)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-3
    sched = build_matmul_schedule(OCTA, MatmulProblem(n, n, n))
    assert schedule_totals(sched)["macs"] == n ** 3


def test_serving_is_time_predictable_by_construction():
    """Static decode program: two runs of the same step are identical
    (no data-dependent shapes anywhere)."""
    from conftest import TINY_OPTS, tiny_cfg
    from repro.models import decode_step, init_cache, init_params
    cfg = tiny_cfg("qwen2-0.5b", num_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 2, 32)
    tok = jnp.array([3, 5], jnp.int32)
    l1, c1 = decode_step(cfg, params, cache, tok, 8, TINY_OPTS)
    l2, c2 = decode_step(cfg, params, cache, tok, 8, TINY_OPTS)
    assert jnp.array_equal(l1, l2)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert jnp.array_equal(a, b)
