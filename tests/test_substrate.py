"""Substrate tests: data determinism, checkpoint atomicity/roundtrip,
optimizer behaviour, fault-tolerance building blocks."""
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.optim.adamw import adamw_init, adamw_update, cosine_lr
from repro.runtime.fault import (PreemptionGuard, StragglerMonitor,
                                 elastic_remesh_plan)


# ------------------------------------------------------------------ data

def test_data_deterministic_across_instances():
    cfg = DataConfig(vocab_size=128, global_batch=4, seq_len=16)
    a = SyntheticLMDataset(cfg).batch_at(7)
    b = SyntheticLMDataset(cfg).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLMDataset(cfg).batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_targets_shifted():
    cfg = DataConfig(vocab_size=128, global_batch=2, seq_len=16)
    b = SyntheticLMDataset(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_data_markov_structure_predictable():
    cfg = DataConfig(vocab_size=64, global_batch=8, seq_len=64)
    ds = SyntheticLMDataset(cfg)
    b = ds.batch_at(0)
    pred = ds._perm[b["tokens"]]
    acc = (pred == b["targets"]).mean()
    assert acc > 0.8    # 10% noise -> ~90% predictable


# ------------------------------------------------------------ checkpoint

def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    cm.save(10, tree)
    restored, step = cm.restore(tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep_n=2)
    t = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3):
        cm.save(s, t)
    assert sorted(cm.all_steps()) == [2, 3]
    assert cm.latest_step() == 3


def test_checkpoint_async_and_atomic(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = {"x": jnp.arange(1000, dtype=jnp.float32)}
    cm.save(5, t, blocking=False)
    cm.wait()
    assert not list(pathlib.Path(tmp_path).glob(".tmp_*"))
    restored, _ = cm.restore(t)
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(t["x"]))


def test_checkpoint_shape_mismatch_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"x": jnp.zeros((4,))})
    with pytest.raises(AssertionError):
        cm.restore({"x": jnp.zeros((5,))})


# -------------------------------------------------------------- optimizer

def test_adamw_minimizes_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0,
                       total_steps=100, weight_decay=0.0)
    lr_fn = cosine_lr(tcfg)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, opt, info = adamw_update(g, opt, params, tcfg, lr_fn)
    assert float(loss(params)) < 0.2
    assert float(info["grad_norm"]) >= 0


def test_grad_clip_bounds_update():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=0, grad_clip=1.0,
                       weight_decay=0.0)
    lr_fn = lambda s: jnp.float32(1.0)
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    p2, _, info = adamw_update(g, opt, params, tcfg, lr_fn)
    assert float(info["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0


# ---------------------------------------------------------- fault blocks

def test_preemption_guard_flag():
    g = PreemptionGuard(signals=())
    assert not g.preempted
    g.trigger_for_test()
    assert g.preempted


def test_straggler_monitor_flags_slow_step(monkeypatch):
    m = StragglerMonitor(threshold=2.0)
    times = iter([0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 13.0])
    monkeypatch.setattr("time.monotonic", lambda: next(times))
    for step in range(3):
        m.step_start()
        assert not m.step_end(step)
    m.step_start()
    assert m.step_end(3)      # 10s step vs ~1s mean
    assert m.events and m.events[0][0] == 3


@given(n=st.integers(1, 4096))
@settings(max_examples=50, deadline=None)
def test_elastic_remesh_plan_valid(n):
    plan = elastic_remesh_plan(n)
    assert plan["devices_used"] <= n
    assert plan["devices_used"] == plan["data"] * plan["model"]
    assert plan["data"] >= 1 and plan["model"] >= 1
    assert plan["grad_accum_factor"] >= 1
