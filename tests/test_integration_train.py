"""End-to-end integration: training reduces loss on learnable synthetic
data; checkpoint-restart resumes exactly; sharding rules unit behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig
from repro.models.lm import RunOptions
from repro.runtime.trainer import Trainer


def _trainer(tmp=None, steps=40):
    cfg = tiny_cfg("qwen2-0.5b", num_layers=2, d_model=64, d_ff=128,
                   vocab_size=64, vocab_pad_multiple=64)
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5,
                       total_steps=steps, seed=0)
    dcfg = DataConfig(vocab_size=64, global_batch=8, seq_len=32)
    opts = RunOptions(chunk_q=16, chunk_kv=16, loss_chunk=16, remat=False)
    return Trainer(cfg, tcfg, dcfg, ckpt_dir=tmp, ckpt_every=10,
                   opts=opts, log_every=0)


def test_loss_decreases():
    tr = _trainer(steps=80)
    hist = tr.run(80)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    # markov data is 90% predictable; the model must beat uniform
    assert last < first - 0.5, (first, last)


def test_checkpoint_restart_resumes_exactly(tmp_path):
    tr1 = _trainer(str(tmp_path / "a"), steps=20)
    h1 = tr1.run(20)

    # train 10 steps, "crash", resume to 20 in a new Trainer
    tr2a = _trainer(str(tmp_path / "b"), steps=20)
    tr2a.run(10)
    tr2b = _trainer(str(tmp_path / "b"), steps=20)
    assert tr2b.ckpt.latest_step() == 10
    h2 = tr2b.run(20)

    p1 = jax.tree.leaves(tr1.final_state.params)
    p2 = jax.tree.leaves(tr2b.final_state.params)
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)
    assert abs(h1["loss"][-1] - h2["loss"][-1]) < 0.1


def test_preemption_checkpoints_and_exits(tmp_path):
    tr = _trainer(str(tmp_path), steps=100)
    n_at_preempt = []

    def cb(step, metrics):
        if step == 5:
            tr.guard.trigger_for_test()
            n_at_preempt.append(step)

    tr.on_metrics = cb
    tr.run(100)
    assert n_at_preempt == [5]
    assert tr.final_state.step == 5 or tr.final_state.step == 6
    assert tr.ckpt.latest_step() is not None


def test_microbatch_matches_full_batch():
    """Gradient accumulation is numerically consistent (distributed-
    optimization trick validated)."""
    from repro.optim.adamw import make_train_step
    from repro.models import init_params
    from repro.optim.adamw import adamw_init
    cfg = tiny_cfg("qwen2-0.5b", num_layers=1, d_model=64, d_ff=128,
                   vocab_size=64, vocab_pad_multiple=64, dtype="float32")
    opts = RunOptions(chunk_q=16, chunk_kv=16, loss_chunk=0, remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (8, 32), 0, 64)
    batch = {"tokens": toks, "targets": toks}
    s_full = make_train_step(cfg, TrainConfig(microbatch=0,
                                              warmup_steps=0), opts)
    s_micro = make_train_step(cfg, TrainConfig(microbatch=4,
                                               warmup_steps=0), opts)
    p1, _, m1 = s_full(params, adamw_init(params), batch)
    p2, _, m2 = s_micro(params, adamw_init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


# ------------------------------------------------------- sharding rules

def test_sharding_rules_divisibility_fallback():
    from repro.sharding.rules import ShardingRules

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    r = ShardingRules(mesh=FakeMesh(), batch_axes=("data",),
                      fsdp_axes=("data",), tensor_axes=("model",))
    # 14 heads don't divide 16 -> replicated; d_model divides -> fsdp
    spec = r.spec_for(("embed", "heads", None), (896, 14, 64))
    assert spec == jax.sharding.PartitionSpec("data")
    # 64 heads divide -> model
    spec = r.spec_for(("embed", "heads", None), (8192, 64, 128))
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # an axis is used at most once per array
    spec = r.spec_for(("experts", "embed", "ffn"), (128, 4096, 1536))
    assert spec == jax.sharding.PartitionSpec("model", "data")


def test_sharding_rules_shapes_regimes():
    from repro.launch.mesh import make_production_mesh  # noqa: F401
    from repro.sharding.rules import make_rules

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")

    r = make_rules(FakeMesh(), "train", 256)
    assert r.batch_axes == ("pod", "data")
    r = make_rules(FakeMesh(), "decode", 128)
    assert r.kv_seq_axes == ("model",)
    assert r.batch_axes == ("pod", "data")    # 128 % 32 == 0 -> full
    r = make_rules(FakeMesh(), "prefill", 8)
    assert r.batch_axes == ("data",)          # 8 % 32 != 0 fallback
    r = make_rules(FakeMesh(), "decode", 1)
    assert r.batch_axes == ()
    assert r.kv_seq_axes == ("data", "model")
