"""Hypothesis properties over *randomized schedule IR* (not just the
matmul scheduler's output): the WCET sandwich

    simulate(s, any seed)  <=  wcet(s)  <=  wcet_serial_bound(s)

must hold for every well-formed phase DAG, and the worst-case
evaluation must be seed-invariant — the compositionality invariant
documented in core/wcet.py, strengthened here to arbitrary DAG shapes,
resource mixes, and dependency patterns.

The outer slice deliberately uses ``wcet_serial_bound``, not
``wcet_closed_form``: randomized DAGs can weave a dependency chain
core0 -> DMA -> core1 and beat ``dma_total + longest_core`` (found by
fuzzing exactly this property — see the domain note in core/wcet.py).
The closed form keeps its own sandwich below, restricted to the
scheduler-emitted class it is documented for.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.multivic_paper import (DUAL, HEXADECA,  # noqa: E402
                                          OCTA, QUAD)
from repro.core.schedule import DMA, Schedule, core_resource  # noqa: E402
from repro.core.scheduler import (MatmulProblem,  # noqa: E402
                                  build_matmul_schedule)
from repro.core.simulator import simulate  # noqa: E402
from repro.core.wcet import (jitter_bound, wcet,  # noqa: E402
                             wcet_closed_form, wcet_serial_bound)
from repro.obs import TraceRecorder  # noqa: E402


@st.composite
def schedules(draw):
    """A random well-formed phase DAG: mixed DMA/compute phases on up
    to 4 cores, dependencies only on earlier phases."""
    n = draw(st.integers(min_value=1, max_value=25))
    n_cores = draw(st.integers(min_value=1, max_value=4))
    sched = Schedule(meta={"random": True})
    for pid in range(n):
        deps = tuple(sorted(draw(st.sets(
            st.integers(0, pid - 1), max_size=3)))) if pid else ()
        kind = draw(st.sampled_from(["dma_load", "dma_store", "compute"]))
        if kind == "compute":
            core = draw(st.integers(0, n_cores - 1))
            sched.add(kind=kind, resource=core_resource(core),
                      deps=deps, spm_core=core,
                      vec_chunks=draw(st.integers(0, 64)),
                      elems=draw(st.integers(0, 32)),
                      macs=draw(st.integers(0, 1 << 20)),
                      tag=f"c{pid}")
        else:
            sched.add(kind=kind, resource=DMA, deps=deps,
                      bytes_moved=draw(st.integers(0, 1 << 16)),
                      tag=f"d{pid}")
    sched.validate_dag()
    sched.validate_interference_freedom()
    return sched


@given(sched=schedules(), seed=st.integers(0, 2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_wcet_sandwich_random_dags(sched, seed):
    t = simulate(sched, OCTA, seed=seed).total_cycles
    w = wcet(sched, OCTA)
    assert t <= w + 1e-6
    assert w <= wcet_serial_bound(sched, OCTA) + 1e-6


@given(hw=st.sampled_from([DUAL, QUAD, OCTA, HEXADECA]),
       m=st.sampled_from([8, 16, 32]),
       k=st.sampled_from([64, 128, 256]),
       n=st.sampled_from([64, 128, 256]),
       seed=st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_wcet_sandwich_scheduler_class(hw, m, k, n, seed):
    """On scheduler-emitted schedules the closed form slots between
    the exact bound and full serialization:
    sim <= wcet <= closed_form <= serial."""
    sched = build_matmul_schedule(hw, MatmulProblem(m, k, n))
    t = simulate(sched, hw, seed=seed).total_cycles
    w = wcet(sched, hw)
    cf = wcet_closed_form(sched, hw)
    assert t <= w + 1e-6
    assert w <= cf + 1e-6
    assert cf <= wcet_serial_bound(sched, hw) + 1e-6


@given(sched=schedules(),
       seed_a=st.integers(0, 2**32 - 1), seed_b=st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_worst_case_is_seed_invariant(sched, seed_a, seed_b):
    wa = simulate(sched, OCTA, seed=seed_a, worst_case=True)
    wb = simulate(sched, OCTA, seed=seed_b, worst_case=True)
    assert wa.total_cycles == wb.total_cycles
    assert wa.per_resource_busy == wb.per_resource_busy
    # and it IS the exact WCET, by definition
    assert wa.total_cycles == wcet(sched, OCTA)


@given(sched=schedules(),
       seeds=st.lists(st.integers(0, 2**16), min_size=2, max_size=5))
@settings(max_examples=25, deadline=None)
def test_spread_within_jitter_bound_random_dags(sched, seeds):
    ts = [simulate(sched, OCTA, seed=s).total_cycles for s in seeds]
    assert max(ts) - min(ts) <= jitter_bound(sched) + 1e-6


@given(sched=schedules(), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_trace_is_faithful_to_sim_accounting(sched, seed):
    """The observability layer must not disagree with the simulator:
    span count == phase count, per-track busy == per-resource busy,
    and no span may end after total_cycles."""
    rec = TraceRecorder(time_unit="cycles")
    res = simulate(sched, OCTA, seed=seed, trace=rec)
    assert len(rec.spans) == res.n_phases
    busy = rec.busy()
    assert set(busy) == set(res.per_resource_busy)
    for k, v in res.per_resource_busy.items():
        assert busy[k] == pytest.approx(v, rel=1e-12, abs=1e-9)
    assert all(s.end <= res.total_cycles + 1e-9 for s in rec.spans)
