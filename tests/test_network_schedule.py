"""Whole-network static scheduling + time-triggered execution
properties (the paper's §4.3 'entire networks' extension)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.multivic_paper import DUAL, OCTA, QUAD
from repro.core.network_scheduler import (build_network_schedule, mlp,
                                          release_times,
                                          simulate_time_triggered,
                                          tt_jitter_bound)
from repro.core.simulator import simulate
from repro.core.wcet import wcet

CONFIGS = [DUAL, QUAD, OCTA]
NETS = [
    mlp(64, [256, 128, 64]),
    mlp(128, [128, 256, 128, 64]),
]


@pytest.mark.parametrize("hw", CONFIGS, ids=lambda h: h.name)
@pytest.mark.parametrize("net_i", range(len(NETS)))
def test_network_schedule_valid(hw, net_i):
    sched = build_network_schedule(hw, NETS[net_i])
    sched.validate_dag()
    sched.validate_interference_freedom()
    total_macs = sum(p.macs for p in sched.phases)
    assert total_macs == sum(l.m * l.k * l.n for l in NETS[net_i])


@given(seed=st.integers(0, 2**16), hw=st.sampled_from(CONFIGS))
@settings(max_examples=20, deadline=None)
def test_time_triggered_always_schedulable(seed, hw):
    net = NETS[0]
    sched = build_network_schedule(hw, net)
    rel = release_times(sched, hw)
    res, ok = simulate_time_triggered(sched, hw, rel, seed=seed)
    assert ok, "dependency missed its release time"
    assert res.total_cycles <= wcet(sched, hw) + 1e-6


@given(seeds=st.lists(st.integers(0, 2**16), min_size=4, max_size=8,
                      unique=True))
@settings(max_examples=10, deadline=None)
def test_time_triggered_kills_jitter(seeds):
    """End-to-end latency variance: event-driven accumulates DMA jitter;
    time-triggered collapses to a single burst's bound."""
    hw = OCTA
    sched = build_network_schedule(hw, NETS[0])
    rel = release_times(sched, hw)
    tt = [simulate_time_triggered(sched, hw, rel, seed=s)[0].total_cycles
          for s in seeds]
    assert max(tt) - min(tt) <= tt_jitter_bound() + 1e-6
    ev = [simulate(sched, hw, seed=s).total_cycles for s in seeds]
    for e, t in zip(ev, tt):
        assert e <= t + 1e-6   # predictability costs latency, bounded:
    assert max(tt) <= wcet(sched, hw) + 1e-6


def test_event_vs_tt_tradeoff_documented():
    hw = OCTA
    sched = build_network_schedule(hw, NETS[1])
    rel = release_times(sched, hw)
    ev = simulate(sched, hw, seed=1).total_cycles
    tt = simulate_time_triggered(sched, hw, rel, seed=1)[0].total_cycles
    w = wcet(sched, hw)
    # the three execution disciplines nest as the paper implies
    assert ev <= tt <= w + 1e-6
    # and the WCET padding is tiny for this compute-bound workload
    assert (tt - ev) / ev < 0.05