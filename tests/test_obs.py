"""Coverage for the predictability observatory (repro.obs):

* TraceRecorder span begin/end nesting and accounting,
* Chrome-trace JSON export round-trips through ``json.loads`` with the
  required ``ph``/``ts``/``dur`` keys,
* jitter_stats against a hand-computed fixture,
* the structured benchmark report (make_report/validate_report) and
  the ``benchmarks/run.py --json`` CLI path,
* the wall-clock producers: StragglerMonitor and the Trainer step loop.
"""
import json
import math
import os
import sys

import pytest

from repro.configs.multivic_paper import QUAD
from repro.core.scheduler import MatmulProblem, build_matmul_schedule
from repro.core.simulator import simulate
from repro.obs import (TraceRecorder, jitter_stats, make_report,
                       simulate_sweep, to_chrome_trace, validate_report,
                       write_chrome_trace)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------- recorder

def test_spans_nest_correctly():
    rec = TraceRecorder()
    rec.begin("outer", track="t", t=0.0)
    rec.begin("inner", track="t", t=1.0)
    inner = rec.end(track="t", t=2.0)
    outer = rec.end(track="t", t=5.0)
    assert inner.name == "inner" and outer.name == "outer"
    assert outer.start <= inner.start and inner.end <= outer.end
    assert rec.open_spans == 0
    assert rec.busy()["t"] == pytest.approx(1.0 + 5.0)


def test_end_without_begin_raises():
    rec = TraceRecorder()
    with pytest.raises(ValueError):
        rec.end(track="nope")


def test_span_context_manager_wall_clock():
    rec = TraceRecorder()
    with rec.span("work", track="main", cat="test", k=1):
        with rec.span("sub", track="main"):
            pass
    assert [s.name for s in rec.spans] == ["sub", "work"]
    sub, work = rec.spans
    assert work.start <= sub.start <= sub.end <= work.end
    assert dict(work.args) == {"k": 1}


def test_independent_tracks_do_not_interfere():
    rec = TraceRecorder()
    rec.begin("a", track="dma", t=0.0)
    rec.begin("b", track="core0", t=1.0)
    rec.end(track="dma", t=4.0)
    rec.end(track="core0", t=2.0)
    assert rec.busy() == {"dma": 4.0, "core0": 1.0}
    assert rec.tracks() == ["core0", "dma"]


# --------------------------------------------------------- chrome trace

def _sample_recorder():
    rec = TraceRecorder(time_unit="cycles")
    rec.add_span("phase0", track="dma", start=0.0, end=10.0,
                 cat="dma_load", pid=0)
    rec.add_span("phase1", track="core0", start=10.0, end=30.0,
                 cat="compute", pid=1)
    rec.counter("loss", 1.5, t=5.0)
    rec.instant("straggler", track="core0", t=20.0, step=3)
    return rec


def test_chrome_trace_round_trips_with_required_keys(tmp_path):
    rec = _sample_recorder()
    path = write_chrome_trace(rec, str(tmp_path / "trace.json"))
    doc = json.loads(open(path, encoding="utf-8").read())
    assert doc["otherData"]["time_unit"] == "cycles"
    events = doc["traceEvents"]
    complete = [e for e in events if e["ph"] == "X"]
    assert len(complete) == 2
    for e in complete:
        for key in ("ph", "ts", "dur", "name", "pid", "tid", "cat"):
            assert key in e, key
    assert {e["ph"] for e in events} == {"M", "X", "C", "i"}
    names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert {"dma", "core0"} <= names
    dur = {e["name"]: e["dur"] for e in complete}
    assert dur == {"phase0": 10.0, "phase1": 20.0}


def test_simulator_trace_exports_loadable_chrome_json(tmp_path):
    sched = build_matmul_schedule(QUAD, MatmulProblem(8, 64, 64))
    rec = TraceRecorder(time_unit="cycles")
    res = simulate(sched, QUAD, seed=3, trace=rec)
    path = write_chrome_trace(rec, str(tmp_path / "sim.json"))
    doc = json.loads(open(path, encoding="utf-8").read())
    complete = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(complete) == res.n_phases
    assert max(e["ts"] + e["dur"] for e in complete) == pytest.approx(
        res.total_cycles)
    cats = {e["cat"] for e in complete}
    assert cats <= {"dma_load", "dma_store", "compute"}


# --------------------------------------------------------------- jitter

def test_jitter_stats_hand_computed_fixture():
    # samples chosen so every metric is checkable by hand
    s = jitter_stats([10.0, 12.0, 11.0, 17.0], wcet_bound=20.0)
    assert s.n == 4
    assert s.mean == pytest.approx(12.5)
    assert s.median == pytest.approx(11.5)
    assert s.std == pytest.approx(math.sqrt(7.25))
    assert s.min == 10.0 and s.max == 17.0
    assert s.spread == pytest.approx(7.0)
    # numpy linear-interpolation percentile: 12 + 0.97 * (17 - 12)
    assert s.p99 == pytest.approx(16.85)
    assert s.cov == pytest.approx(math.sqrt(7.25) / 12.5)
    assert s.wcet_margin == pytest.approx(20.0 / 17.0)
    d = s.as_dict()
    assert set(d) == {"n", "mean", "median", "std", "min", "max",
                      "spread", "p99", "cov", "wcet_margin"}


def test_jitter_stats_rejects_empty():
    with pytest.raises(ValueError):
        jitter_stats([])


def test_simulate_sweep_margin_holds_and_is_seeded():
    sched = build_matmul_schedule(QUAD, MatmulProblem(8, 64, 64))
    a = simulate_sweep(sched, QUAD, n_runs=16, seed0=0)
    b = simulate_sweep(sched, QUAD, n_runs=16, seed0=0)
    assert a == b                       # frozen dataclass, same seeds
    assert a.wcet_margin is not None and a.wcet_margin >= 1.0
    assert a.spread >= 0 and a.cov >= 0


# --------------------------------------------------------------- report

def _rows():
    sched = build_matmul_schedule(QUAD, MatmulProblem(8, 64, 64))
    j = simulate_sweep(sched, QUAD, n_runs=4)
    return [
        {"name": "fig4/quad", "us_per_call": 12.0,
         "derived": "median_cycles=1", "jitter": j.as_dict()},
        {"name": "table12/quad", "us_per_call": 1.0, "derived": "x=1"},
    ]


def test_report_validates_and_round_trips(tmp_path):
    rep = make_report(_rows(), fast=True)
    assert validate_report(rep) == []
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(rep))
    back = json.loads(path.read_text())
    assert validate_report(back) == []
    assert back["schema_version"] == 1
    assert back["hw_fingerprint"]["paper_configs_sha256"]
    assert "jitter" in back["benchmarks"][0]
    assert "jitter" not in back["benchmarks"][1]


def test_report_validation_catches_corruption():
    rep = make_report(_rows(), fast=False)
    assert validate_report({"schema_version": 99})
    bad = json.loads(json.dumps(rep))
    del bad["benchmarks"][0]["us_per_call"]
    assert any("us_per_call" in e for e in validate_report(bad))
    bad2 = json.loads(json.dumps(rep))
    del bad2["benchmarks"][0]["jitter"]["cov"]
    assert any("cov" in e for e in validate_report(bad2))


def test_benchmarks_run_json_cli(tmp_path, capsys):
    """The actual --json CLI path on a cheap suite subset: CSV stdout
    format unchanged, report file schema-valid."""
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks import run as bench_run
    finally:
        sys.path.remove(REPO_ROOT)
    out = tmp_path / "bench.json"
    bench_run.main(["--fast", "--json", str(out),
                    "--only", "table12,fig5"])
    stdout = capsys.readouterr().out
    lines = stdout.strip().splitlines()
    assert lines[0] == "name,us_per_call,derived"
    assert all(line.count(",") >= 2 for line in lines[1:])
    doc = json.loads(out.read_text())
    assert validate_report(doc) == []
    assert doc["fast"] is True
    assert {b["name"].split("/")[0] for b in doc["benchmarks"]} == \
        {"table12", "fig5a", "fig5b"}


# ------------------------------------------------- wall-clock producers

def test_straggler_monitor_feeds_trace(monkeypatch):
    from repro.runtime import fault

    clock = iter([0.0, 0.1,        # step 1: 0.1 s
                  1.0, 1.1,        # step 2: 0.1 s
                  2.0, 3.0])       # step 3: 1.0 s -> straggler
    monkeypatch.setattr(fault.time, "monotonic", lambda: next(clock))
    rec = TraceRecorder()
    mon = fault.StragglerMonitor(trace=rec)
    for step in (1, 2):
        mon.step_start()
        assert mon.step_end(step) is False
    mon.step_start()
    assert mon.step_end(3) is True
    assert [c.value for c in rec.counters] == \
        pytest.approx([0.1, 0.1, 1.0])
    assert [i.name for i in rec.instants] == ["straggler"]
    assert dict(rec.instants[0].args)["step"] == 3


def test_trainer_step_loop_records_spans():
    from conftest import tiny_cfg
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import DataConfig
    from repro.models.lm import RunOptions
    from repro.runtime.trainer import Trainer

    cfg = tiny_cfg("qwen2-0.5b", num_layers=1, d_model=32, d_ff=64,
                   vocab_size=64, vocab_pad_multiple=64)
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=2,
                       total_steps=3, seed=0)
    dcfg = DataConfig(vocab_size=64, global_batch=4, seq_len=16)
    opts = RunOptions(chunk_q=16, chunk_kv=16, loss_chunk=16,
                      remat=False)
    rec = TraceRecorder()
    tr = Trainer(cfg, tcfg, dcfg, opts=opts, log_every=0, trace=rec)
    tr.run(3)
    steps = rec.spans_on("trainer")
    assert [s.name for s in steps] == ["step0", "step1", "step2"]
    assert all(s.cat == "train_step" and s.dur >= 0 for s in steps)
    assert rec.open_spans == 0
    losses = [c for c in rec.counters if c.name == "loss"]
    step_s = [c for c in rec.counters if c.name == "step_s"]
    assert len(losses) == 3 and len(step_s) == 3
