"""Elastic scaling: a checkpoint written under one mesh restores under
a different device count (node-failure recovery path).  Subprocesses
own their device counts (process-global in jax)."""
import subprocess
import sys

_SAVE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.compat import auto_axis_types, make_mesh
mesh = make_mesh((2, 2), ("data", "model"),
                 axis_types=auto_axis_types(2))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh, P("data", "model")))
cm = CheckpointManager(sys.argv[1])
cm.save(7, {"w": w})
print("SAVED")
"""

_RESTORE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.compat import auto_axis_types, make_mesh
from repro.runtime.fault import elastic_remesh_plan
plan = elastic_remesh_plan(len(jax.devices()), model_parallel=2)
mesh = make_mesh((plan["data"], plan["model"]), ("data", "model"),
                 axis_types=auto_axis_types(2))
sh = {"w": NamedSharding(mesh, P("data", "model"))}
cm = CheckpointManager(sys.argv[1])
like = {"w": jnp.zeros((8, 8))}
restored, step = cm.restore(like, shardings=sh)
assert step == 7
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64.0).reshape(8, 8))
assert restored["w"].sharding.mesh.shape["model"] == 2
print("RESTORED", plan)
"""


def test_checkpoint_survives_remesh(tmp_path):
    d = str(tmp_path)
    r1 = subprocess.run([sys.executable, "-c", _SAVE, d], cwd=".",
                        capture_output=True, text=True, timeout=300)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, "-c", _RESTORE, d], cwd=".",
                        capture_output=True, text=True, timeout=300)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "RESTORED" in r2.stdout
