"""Elastic scaling: a checkpoint written under one mesh restores under
a different device count (node-failure recovery path).  Subprocesses
own their device counts (process-global in jax)."""
import subprocess
import sys

_SAVE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.compat import auto_axis_types, make_mesh
mesh = make_mesh((2, 2), ("data", "model"),
                 axis_types=auto_axis_types(2))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh, P("data", "model")))
cm = CheckpointManager(sys.argv[1])
cm.save(7, {"w": w})
print("SAVED")
"""

_RESTORE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.compat import auto_axis_types, make_mesh
from repro.runtime.fault import elastic_remesh_plan
plan = elastic_remesh_plan(len(jax.devices()), model_parallel=2)
mesh = make_mesh((plan["data"], plan["model"]), ("data", "model"),
                 axis_types=auto_axis_types(2))
sh = {"w": NamedSharding(mesh, P("data", "model"))}
cm = CheckpointManager(sys.argv[1])
like = {"w": jnp.zeros((8, 8))}
restored, step = cm.restore(like, shardings=sh)
assert step == 7
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64.0).reshape(8, 8))
assert restored["w"].sharding.mesh.shape["model"] == 2
print("RESTORED", plan)
"""


def test_remesh_plan_edge_cases():
    """Failure-path inputs: the plan must stay internally consistent
    for any survivor count the scheduler can hand it."""
    import pytest

    from repro.runtime.fault import elastic_remesh_plan

    for n in (0, 1, 2, 3, 5, 6, 7, 12, 15, 16, 17, 100):
        plan = elastic_remesh_plan(n, model_parallel=16)
        assert plan["devices_used"] + plan["devices_idle"] == n, (n, plan)
        assert plan["grad_accum_factor"] >= 1, (n, plan)
        assert plan["devices_used"] == plan["data"] * plan["model"]

    # n_devices below model_parallel degrades to a power of two
    assert elastic_remesh_plan(6, model_parallel=16)["model"] == 4
    assert elastic_remesh_plan(1, model_parallel=16) == {
        "data": 1, "model": 1, "devices_used": 1, "devices_idle": 0,
        "grad_accum_factor": 16}
    # total outage: a degenerate-but-consistent plan, not a crash
    z = elastic_remesh_plan(0)
    assert z["devices_used"] == 0 and z["devices_idle"] == 0
    # an unsatisfiable data-parallel floor is an explicit error, never
    # a plan that oversubscribes the survivors
    with pytest.raises(ValueError):
        elastic_remesh_plan(4, model_parallel=4, min_data=2)


def test_straggler_monitor_unpaired_step_end():
    """step_end() without a prior step_start() is a no-op, not a
    TypeError (restart paths call step_end defensively)."""
    from repro.runtime.fault import StragglerMonitor

    mon = StragglerMonitor()
    assert mon.step_end(0) is False
    assert mon.mean_step_s is None and mon.events == []
    # a normal pair afterwards still records
    mon.step_start()
    assert mon.step_end(1) is False
    assert mon.mean_step_s is not None
    # step_end consumed the start: calling again is again a no-op
    before = mon.mean_step_s
    assert mon.step_end(2) is False
    assert mon.mean_step_s == before


def test_checkpoint_survives_remesh(tmp_path):
    d = str(tmp_path)
    r1 = subprocess.run([sys.executable, "-c", _SAVE, d], cwd=".",
                        capture_output=True, text=True, timeout=300)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, "-c", _RESTORE, d], cwd=".",
                        capture_output=True, text=True, timeout=300)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "RESTORED" in r2.stdout
