"""Integration correctness: step-by-step decode with a cache must
reproduce the full-forward logits (teacher forcing) — validates cache
semantics for every layer family (GQA, sliding-window, MoE, Mamba2
conv+ssm state, RWKV6 shift+wkv state, enc-dec cross-attn)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import TINY_LAYERS, tiny_cfg
from repro.models import (compute_logits, decode_step, forward_hidden,
                          init_params, prefill)
from repro.models.lm import RunOptions

ARCHS = ["gemma3-12b", "zamba2-7b", "rwkv6-1.6b", "qwen3-moe-235b-a22b",
         "whisper-base", "qwen2-72b", "qwen2-0.5b", "deepseek-67b",
         "pixtral-12b", "llama4-maverick-400b-a17b"]
B, S, EXTRA = 2, 32, 6


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = tiny_cfg(arch, num_layers=TINY_LAYERS[arch], dtype="float32")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, S + EXTRA), 0, cfg.vocab_size)
    bf = {"tokens": toks, "targets": toks}
    bp = {"tokens": toks[:, :S], "targets": toks[:, :S]}
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, 32, cfg.d_model), jnp.float32)
        bf["frames"] = bp["frames"] = frames
    opts = RunOptions(chunk_q=8, chunk_kv=8, cache_len=S + EXTRA,
                      remat=False)
    x, _, _ = forward_hidden(cfg, params, bf, opts)
    want = compute_logits(cfg, params, x[:, -1])
    lg, cache = prefill(cfg, params, bp, opts)
    for t in range(EXTRA):
        lg, cache = decode_step(cfg, params, cache, toks[:, S + t],
                                S + t, opts)
    got, want = lg[:, :cfg.vocab_size], want[:, :cfg.vocab_size]
    rel = float(jnp.max(jnp.abs(got - want))) / (
        float(jnp.max(jnp.abs(want))) + 1e-9)
    assert rel < 2e-2, (arch, rel)


def test_windowed_ring_cache_matches_full(monkeypatch):
    """wincache variant: sliding-window layers keep an O(window) ring
    buffer; decode must still reproduce the full forward exactly
    (gemma3-style 5:1 local:global pattern)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import compute_logits, forward_hidden, init_params
    cfg = get_config("gemma3-12b")
    cfg = dataclasses.replace(
        cfg, num_layers=6, d_model=128, d_ff=256, vocab_size=512,
        vocab_pad_multiple=64, dtype="float32",
        attention=dataclasses.replace(cfg.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=32,
                                      sliding_window=8))
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, 32 + 10), 0, cfg.vocab_size)
    x, _, _ = forward_hidden(cfg, params, {"tokens": toks},
                             RunOptions(chunk_q=0, chunk_kv=0,
                                        remat=False))
    want = compute_logits(cfg, params, x[:, -1])
    opts = RunOptions(chunk_q=0, chunk_kv=0, cache_len=42, remat=False,
                      windowed_cache=True)
    lg, cache = prefill(cfg, params, {"tokens": toks[:, :32]}, opts)
    assert cache["stage0"]["pos0"]["k"].shape[2] == 8   # ring!
    assert cache["stage0"]["pos5"]["k"].shape[2] == 42  # global: full
    for t in range(10):
        lg, cache = decode_step(cfg, params, cache, toks[:, 32 + t],
                                32 + t, opts)
    rel = float(jnp.max(jnp.abs(
        lg[:, :cfg.vocab_size] - want[:, :cfg.vocab_size]))) / float(
        jnp.max(jnp.abs(want[:, :cfg.vocab_size])))
    assert rel < 2e-2, rel
