"""Repo hygiene, enforced as tier-1: compiled artifacts must never be
tracked (PR 6 accidentally committed ``__pycache__/*.pyc``; this keeps
that from recurring) and the ignore rules that prevent it must stay in
place — while BENCH_*.json perf reports remain trackable so the perf
trajectory persists across PRs.
"""
import pathlib
import subprocess

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _git_ls_files():
    try:
        out = subprocess.run(["git", "ls-files"], cwd=REPO_ROOT,
                             capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip(f"not a git checkout: {out.stderr.strip()}")
    return out.stdout.splitlines()


def test_no_compiled_artifacts_tracked():
    tracked = _git_ls_files()
    bad = [f for f in tracked
           if f.endswith(".pyc") or "__pycache__" in f.split("/")]
    assert not bad, f"compiled artifacts tracked in git: {bad}"


def test_every_src_package_has_init():
    """Every directory under src/ that holds Python modules must be a
    real package — a missing __init__.py makes modules importable in
    the dev checkout (sys.path tricks) but invisible to an installed
    wheel, which is exactly the kind of drift that only bites in CI.
    Every ancestor up to src/ must be a package too: an intermediate
    directory holding only subpackages still needs __init__.py for
    the installed-wheel import chain."""
    src = REPO_ROOT / "src"
    missing = set()
    for p in src.rglob("*.py"):
        d = p.parent
        while d != src:
            if not (d / "__init__.py").exists():
                missing.add(str(d.relative_to(REPO_ROOT)))
            d = d.parent
    assert not missing, \
        f"directories missing __init__.py: {sorted(missing)}"


def test_resilience_layer_is_accelerator_free():
    """The chaos/retry/deadline layer must stay importable without
    jax: fault planning and degradation policy are host-side concerns,
    and keeping them dependency-free is what lets the plan cache and
    checkpoint code reuse them on any backend (docstring contract in
    src/repro/resilience/__init__.py)."""
    res = REPO_ROOT / "src" / "repro" / "resilience"
    assert res.is_dir()
    offenders = []
    for p in sorted(res.rglob("*.py")):
        for lineno, line in enumerate(
                p.read_text(encoding="utf-8").splitlines(), 1):
            s = line.strip()
            if s.startswith(("import jax", "from jax")):
                offenders.append(
                    f"{p.relative_to(res)}:{lineno}: {s}")
    assert not offenders, \
        f"resilience/ must not import jax: {offenders}"


def test_gitignore_covers_cache_dirs_but_not_bench_reports():
    gi = (REPO_ROOT / ".gitignore").read_text(encoding="utf-8")
    rules = {line.strip() for line in gi.splitlines()
             if line.strip() and not line.startswith("#")}
    for pattern in ("__pycache__/", "*.pyc", ".pytest_cache/"):
        assert pattern in rules, f".gitignore missing {pattern!r}"
    # the perf trajectory must stay committable
    assert not any("BENCH" in r for r in rules), \
        "BENCH_*.json reports must not be git-ignored"
