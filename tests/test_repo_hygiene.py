"""Repo hygiene, enforced as tier-1: compiled artifacts must never be
tracked (PR 6 accidentally committed ``__pycache__/*.pyc``; this keeps
that from recurring) and the ignore rules that prevent it must stay in
place — while BENCH_*.json perf reports remain trackable so the perf
trajectory persists across PRs.
"""
import pathlib
import subprocess

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _git_ls_files():
    try:
        out = subprocess.run(["git", "ls-files"], cwd=REPO_ROOT,
                             capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip(f"not a git checkout: {out.stderr.strip()}")
    return out.stdout.splitlines()


def test_no_compiled_artifacts_tracked():
    tracked = _git_ls_files()
    bad = [f for f in tracked
           if f.endswith(".pyc") or "__pycache__" in f.split("/")]
    assert not bad, f"compiled artifacts tracked in git: {bad}"


def test_gitignore_covers_cache_dirs_but_not_bench_reports():
    gi = (REPO_ROOT / ".gitignore").read_text(encoding="utf-8")
    rules = {line.strip() for line in gi.splitlines()
             if line.strip() and not line.startswith("#")}
    for pattern in ("__pycache__/", "*.pyc", ".pytest_cache/"):
        assert pattern in rules, f".gitignore missing {pattern!r}"
    # the perf trajectory must stay committable
    assert not any("BENCH" in r for r in rules), \
        "BENCH_*.json reports must not be git-ignored"
