"""Per-architecture smoke tests (assignment deliverable f): a reduced
config of the same family runs one forward/train step on CPU and one
prefill+decode step; output shapes and finiteness asserted."""
import jax
import jax.numpy as jnp
import pytest

from conftest import TINY_LAYERS, TINY_OPTS, tiny_cfg
from repro.configs.all_archs import ALL_ARCH_IDS
from repro.models import (decode_step, init_params, prefill, train_loss)
from repro.models.lm import RunOptions

B, S = 2, 64


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32)
    if cfg.family == "vlm" and cfg.frontend.num_positions:
        batch["patch_embeds"] = jax.random.normal(
            key, (B, min(8, cfg.frontend.num_positions), cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = tiny_cfg(arch, num_layers=TINY_LAYERS[arch])
    if cfg.family == "vlm":
        import dataclasses
        cfg = dataclasses.replace(
            cfg, frontend=dataclasses.replace(cfg.frontend,
                                              num_positions=8))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    loss = jax.jit(lambda p, b: train_loss(cfg, p, b, TINY_OPTS))(
        params, _batch(cfg, key))
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, float(loss))
    # sane magnitude: near ln(vocab) at init
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))


@pytest.mark.parametrize("arch", ALL_ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = tiny_cfg(arch, num_layers=TINY_LAYERS[arch])
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    opts = RunOptions(chunk_q=16, chunk_kv=16, cache_len=S + 4,
                      remat=False)
    logits, cache = jax.jit(
        lambda p, b: prefill(cfg, p, b, opts))(params, _batch(cfg, key))
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size]))
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, S, opts))(
        params, cache, tok)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits2[:, :cfg.vocab_size]))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
