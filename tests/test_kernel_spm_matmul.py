"""spm_matmul Pallas kernel vs pure-jnp oracle (interpret mode on CPU):
shape/dtype sweep per the assignment."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.spm_matmul.ops import matmul, vmem_plan
from repro.kernels.spm_matmul.ref import matmul_ref

CASES = [
    # (m, k, n, bm, bn, bk, dtype, rtol)
    (128, 128, 128, 128, 128, 0, jnp.float32, 1e-5),
    (256, 512, 256, 128, 128, 0, jnp.float32, 1e-5),
    (256, 256, 512, 128, 256, 0, jnp.bfloat16, 2e-2),
    (256, 512, 256, 128, 128, 128, jnp.float32, 1e-5),
    (512, 1024, 512, 256, 256, 256, jnp.bfloat16, 2e-2),
    (128, 384, 128, 64, 128, 128, jnp.float32, 1e-5),
]


@pytest.mark.parametrize("m,k,n,bm,bn,bk,dtype,rtol", CASES)
def test_matmul_matches_ref(m, k, n, bm, bn, bk, dtype, rtol):
    ka, kb = jax.random.split(jax.random.PRNGKey(m + n + k))
    a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    got = matmul(a, b, bm=bm, bn=bn, bk=bk).astype(jnp.float32)
    want = matmul_ref(a, b).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    assert float(jnp.max(jnp.abs(got - want))) / scale < rtol


def test_vmem_plan_is_schedule_feasibility():
    # the paper's regime: B block + double-buffered A/C must fit VMEM
    plan = vmem_plan(1024, 1024, 1024, bm=256, bn=256, bk=0,
                     elem_bytes=2)
    assert plan["fits"]
    plan_big = vmem_plan(1024, 65536, 1024, bm=512, bn=512, bk=0,
                         elem_bytes=4)
    assert not plan_big["fits"]   # K too large to pin -> must k-split


def test_matmul_autosplits_oversized_k():
    a = jnp.ones((128, 2048), jnp.float32)
    b = jnp.ones((2048, 128), jnp.float32)
    out = matmul(a, b, bm=128, bn=128, bk=512)
    assert jnp.allclose(out, 2048.0)
