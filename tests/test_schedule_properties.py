"""Hypothesis property tests on the static-schedule system invariants:

  * schedules are valid DAGs and interference-free by construction,
  * work conservation: total MACs == m*k*n for any config/problem,
  * any simulated execution <= WCET (the paper's compositionality claim),
  * exact WCET <= closed-form bound,
  * observed spread <= analytic jitter bound,
  * determinism: same seed -> same cycles.
"""
import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.multivic_paper import (BASELINE_FAST, DUAL, HEXADECA,
                                          OCTA, QUAD, MultiVicConfig,
                                          VicunaConfig)
from repro.core.scheduler import (MatmulProblem, build_matmul_schedule,
                                  schedule_totals, spm_plan)
from repro.core.simulator import simulate
from repro.core.wcet import jitter_bound, wcet, wcet_closed_form

CONFIGS = [BASELINE_FAST, DUAL, QUAD, OCTA, HEXADECA]

hw_strategy = st.sampled_from(CONFIGS)
size_strategy = st.sampled_from([64, 128, 256])


@st.composite
def problems(draw):
    m = draw(size_strategy)
    k = draw(size_strategy)
    n = draw(size_strategy)
    return MatmulProblem(m, k, n)


@given(hw=hw_strategy, prob=problems())
@settings(max_examples=25, deadline=None)
def test_schedule_work_conservation(hw, prob):
    sched = build_matmul_schedule(hw, prob, rows_per_transfer=4)
    tot = schedule_totals(sched)
    assert tot["macs"] == prob.macs


@given(hw=hw_strategy, prob=problems(), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_sim_never_exceeds_wcet(hw, prob, seed):
    sched = build_matmul_schedule(hw, prob, rows_per_transfer=4)
    t = simulate(sched, hw, seed=seed).total_cycles
    w = wcet(sched, hw)
    assert t <= w + 1e-6


@given(hw=hw_strategy, prob=problems())
@settings(max_examples=15, deadline=None)
def test_wcet_below_closed_form(hw, prob):
    sched = build_matmul_schedule(hw, prob, rows_per_transfer=4)
    assert wcet(sched, hw) <= wcet_closed_form(hw=hw, sched=sched) + 1e-6


@given(hw=hw_strategy, prob=problems(),
       seeds=st.lists(st.integers(0, 2**16), min_size=3, max_size=6))
@settings(max_examples=10, deadline=None)
def test_spread_within_jitter_bound(hw, prob, seeds):
    sched = build_matmul_schedule(hw, prob, rows_per_transfer=4)
    ts = [simulate(sched, hw, seed=s).total_cycles for s in seeds]
    assert max(ts) - min(ts) <= jitter_bound(sched) + 1e-6


@given(hw=hw_strategy, seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_simulation_deterministic(hw, seed):
    sched = build_matmul_schedule(hw, MatmulProblem(64, 64, 64))
    a = simulate(sched, hw, seed=seed).total_cycles
    b = simulate(sched, hw, seed=seed).total_cycles
    assert a == b


@given(hw=hw_strategy, prob=problems())
@settings(max_examples=15, deadline=None)
def test_spm_plan_always_fits(hw, prob):
    plan = spm_plan(hw, prob, rows_per_transfer=4)
    assert plan["fits"]
    assert plan["bw"] >= plan["vl"]
    # the chosen block really fits beside the double buffers
    need = (prob.k * plan["bw"] + 2 * 4 * prob.k + 2 * 4 * plan["bw"]) * 4
    assert need <= hw.data_spm_bytes


def test_interference_freedom_validated():
    sched = build_matmul_schedule(OCTA, MatmulProblem(64, 64, 64))
    sched.validate_interference_freedom()
    # corrupting a compute phase to touch another core's SPM must fail
    import dataclasses
    bad = dataclasses.replace(sched.phases[5], spm_core=99) \
        if sched.phases[5].kind == "compute" else None
    for i, p in enumerate(sched.phases):
        if p.kind == "compute":
            sched.phases[i] = dataclasses.replace(p, spm_core=99)
            break
    with pytest.raises(AssertionError):
        sched.validate_interference_freedom()
