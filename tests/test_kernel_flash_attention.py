"""flash_attention Pallas kernel vs oracle: causal/window/GQA sweep."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref

CASES = [
    # (B, Sq, Sk, H, KV, D, causal, window, bq, bk, dtype, rtol)
    (2, 128, 128, 4, 2, 64, True, 0, 64, 64, jnp.float32, 1e-5),
    (1, 256, 256, 8, 8, 64, True, 64, 128, 128, jnp.float32, 1e-5),
    (2, 128, 128, 4, 4, 128, False, 0, 64, 64, jnp.float32, 1e-5),
    (1, 256, 256, 4, 1, 64, True, 0, 128, 64, jnp.float32, 1e-5),  # MQA
    (2, 128, 128, 4, 2, 64, True, 32, 64, 64, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize(
    "B,Sq,Sk,H,KV,D,causal,window,bq,bk,dtype,rtol", CASES)
def test_flash_matches_ref(B, Sq, Sk, H, KV, D, causal, window, bq, bk,
                           dtype, rtol):
    ks = jax.random.split(jax.random.PRNGKey(Sq + H + D), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), jnp.float32).astype(dtype)
    got = attention(q, k, v, causal=causal, window=window, bq=bq,
                    bk=bk).astype(jnp.float32)
    want = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=causal,
                         window=window)
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    assert float(jnp.max(jnp.abs(got - want))) / scale < rtol


def test_sliding_window_equals_model_mask():
    """The kernel's window semantics match the model's sdpa mask."""
    from repro.models.attention import sdpa
    B, S, H, KV, D, W = 1, 128, 4, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    pos = jnp.arange(S)
    want = sdpa(q, k, v, pos, pos, causal=True, window=W,
                scale=1.0 / D ** 0.5, chunk_q=0, chunk_kv=0)
    got = attention(q, k, v, causal=True, window=W, bq=64, bk=64)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4
