"""The MultiVic -> TPU bridge: schedule validity, WCET ordering, VMEM
feasibility — time-predictability carried to the target hardware."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.tpu_mapping import (V5E, tpu_matmul_schedule,
                                    tpu_steady_state, tpu_wcet)


@given(m=st.sampled_from([512, 1024]), k=st.sampled_from([512, 1024]),
       n=st.sampled_from([512, 1024]), nd=st.sampled_from([1, 2, 4]))
@settings(max_examples=15, deadline=None)
def test_tpu_schedule_valid_and_bounded(m, k, n, nd):
    if n % nd:
        return
    sched = tpu_matmul_schedule(m, k, n, n_devices=nd)
    sched.validate_dag()
    sched.validate_interference_freedom()
    w = tpu_wcet(sched)
    s = tpu_steady_state(sched)
    assert 0 < s <= w    # overlap estimate never exceeds the bound


def test_vmem_feasibility_reported():
    sched = tpu_matmul_schedule(4096, 8192, 4096, tile_m=512, tile_n=512)
    assert sched.meta["vmem_need"] <= V5E.vmem_bytes
    assert sched.meta["vmem_ok"]


def test_wcet_scales_down_with_devices():
    one = tpu_wcet(tpu_matmul_schedule(2048, 2048, 2048, n_devices=1))
    four = tpu_wcet(tpu_matmul_schedule(2048, 2048, 2048, n_devices=4))
    # DMA is shared (the paper's serialized management DMA) but compute
    # parallelizes: 4 devices must be meaningfully faster
    assert four < one
