"""Kernel-conformance harness: every kernel registered in
``repro.kernels.conformance_cases()`` runs in interpret mode on CPU and
must match its ref.py oracle under the shared tolerance policy
(conftest.KERNEL_TOLERANCES).  One parametrization table covers all
kernels — registering a new kernel is the only step needed to get
coverage here.

Every case's wall-time is recorded as a span on the shared
``RECORDER`` (track ``kernel_conformance``); set
``REPRO_TRACE=/path/kernels.json`` to dump the Chrome trace after the
session (repro.obs).

Collected as part of tier-1 via ``python_files`` in pyproject.toml.
"""
import os

import pytest

from conftest import assert_kernel_close
from repro.kernels import conformance_cases
from repro.obs import TraceRecorder, write_chrome_trace

CASES = conformance_cases()
RECORDER = TraceRecorder(time_unit="us")


@pytest.fixture(scope="module", autouse=True)
def _dump_kernel_trace():
    yield
    path = os.environ.get("REPRO_TRACE")
    if path and RECORDER.spans:
        write_chrome_trace(RECORDER, path)


def test_registry_covers_all_kernel_dirs():
    """Every kernel directory (<name>/ops.py + ref.py) has at least one
    registered conformance case — a new kernel cannot silently ship
    without oracle coverage."""
    import pathlib

    import repro.kernels as kpkg
    root = pathlib.Path(kpkg.__file__).parent
    dirs = {p.parent.name for p in root.glob("*/ref.py")}
    registered = {c.kernel for c in CASES}
    assert dirs == registered, (dirs, registered)


@pytest.mark.parametrize("case", CASES, ids=[c.id for c in CASES])
def test_kernel_matches_oracle(case):
    with RECORDER.span(case.id, track="kernel_conformance",
                       cat="kernel", kernel=case.kernel,
                       dtype=case.dtype):
        got, want = case.run_pair()
    assert_kernel_close(got, want, case.dtype, tol=case.tol)
