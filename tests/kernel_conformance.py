"""Kernel-conformance harness: every kernel registered in
``repro.kernels.conformance_cases()`` runs in interpret mode on CPU and
must match its ref.py oracle under the shared tolerance policy
(conftest.KERNEL_TOLERANCES).  One parametrization table covers all
kernels — registering a new kernel is the only step needed to get
coverage here.

Collected as part of tier-1 via ``python_files`` in pyproject.toml.
"""
import pytest

from conftest import assert_kernel_close
from repro.kernels import conformance_cases

CASES = conformance_cases()


def test_registry_covers_all_kernel_dirs():
    """Every kernel directory (<name>/ops.py + ref.py) has at least one
    registered conformance case — a new kernel cannot silently ship
    without oracle coverage."""
    import pathlib

    import repro.kernels as kpkg
    root = pathlib.Path(kpkg.__file__).parent
    dirs = {p.parent.name for p in root.glob("*/ref.py")}
    registered = {c.kernel for c in CASES}
    assert dirs == registered, (dirs, registered)


@pytest.mark.parametrize("case", CASES, ids=[c.id for c in CASES])
def test_kernel_matches_oracle(case):
    got, want = case.run_pair()
    assert_kernel_close(got, want, case.dtype, tol=case.tol)
