"""Golden-trace timing regression for the cycle-accurate simulator.

The paper's claim is a *statically determined* memory schedule: for a
fixed seed and hardware config the simulated execution is a pure
function of the schedule.  These goldens pin the observable behavior —
exact phase execution order, per-resource busy cycles, total cycles,
and the WCET bound — so a timing-model change can never slip through
silently; if one of these moves, the diff is a deliberate
recalibration and the goldens are updated in the same commit.

Config: the paper's Octa design point (Table 2) on a reduced matmul
(16x128x512 — 3 active cores, 4 streaming iterations, 39 phases).
"""
import pytest

from repro.configs.multivic_paper import OCTA
from repro.core.scheduler import MatmulProblem, build_matmul_schedule
from repro.core.simulator import simulate
from repro.core.wcet import wcet
from repro.obs import TraceRecorder

SEED = 1234
PROBLEM = MatmulProblem(16, 128, 512)

GOLD_N_PHASES = 39
GOLD_TOTAL_CYCLES = 2700747.865222937
GOLD_WCET = 2700938.6689111
GOLD_BUSY = {
    "dma": 22292.575844876148,
    "core0": 2683102.6689111,
    "core1": 2683102.6689111,
    "core2": 555124.6901195379,
}
# Execution order (span starts, ties broken by pid): B blocks first,
# then per iteration the A loads are issued BEFORE the previous
# iteration's C stores — the DMA issue-order rule in core/scheduler.py.
GOLD_ORDER = [
    "B->c0", "B->c1", "B->c2",
    "A0->c0", "A0->c1", "C0,0", "A0->c2", "C0,1", "C0,2",
    "A1->c0", "A1->c1", "A1->c2", "C1,2", "C0,0->ddr", "C1,0", "C1,1",
    "C0,1->ddr", "C0,2->ddr",
    "A2->c0", "A2->c1", "A2->c2", "C2,2", "C1,0->ddr", "C2,0", "C2,1",
    "C1,1->ddr", "C1,2->ddr",
    "A3->c0", "A3->c1", "A3->c2", "C3,2", "C2,0->ddr", "C3,0", "C3,1",
    "C2,1->ddr", "C2,2->ddr",
    "C3,0->ddr", "C3,1->ddr", "C3,2->ddr",
]

EXACT = dict(rel=1e-12, abs=1e-6)


def _run(seed=SEED, trace=None):
    sched = build_matmul_schedule(OCTA, PROBLEM)
    return sched, simulate(sched, OCTA, seed=seed, trace=trace)


def test_golden_totals_and_busy_cycles():
    _, res = _run()
    assert res.n_phases == GOLD_N_PHASES
    assert res.total_cycles == pytest.approx(GOLD_TOTAL_CYCLES, **EXACT)
    assert set(res.per_resource_busy) == set(GOLD_BUSY)
    for resource, gold in GOLD_BUSY.items():
        assert res.per_resource_busy[resource] == pytest.approx(
            gold, **EXACT), resource


def test_golden_wcet_bound():
    sched, res = _run()
    assert wcet(sched, OCTA) == pytest.approx(GOLD_WCET, **EXACT)
    assert res.total_cycles <= GOLD_WCET


def test_golden_phase_order():
    rec = TraceRecorder(time_unit="cycles")
    _, res = _run(trace=rec)
    assert len(rec.spans) == res.n_phases
    order = [s.name for s in sorted(
        rec.spans, key=lambda s: (s.start, dict(s.args)["pid"]))]
    assert order == GOLD_ORDER
    # trace busy == simulator busy, per resource
    for resource, gold in res.per_resource_busy.items():
        assert rec.busy()[resource] == pytest.approx(gold, **EXACT)


def test_same_seed_deterministic_different_seed_diverges():
    _, a = _run(seed=7)
    _, b = _run(seed=7)
    assert a.total_cycles == b.total_cycles
    assert a.per_resource_busy == b.per_resource_busy
    _, c = _run(seed=8)
    assert c.total_cycles != a.total_cycles
