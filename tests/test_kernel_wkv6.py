"""WKV6 Pallas kernel vs exact sequential oracle, plus the chunked jnp
form used by the model — all three must agree."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.wkv6.ops import wkv
from repro.kernels.wkv6.ref import wkv6_ref
from repro.models.rwkv import wkv6_chunked

CASES = [
    # (B, S, H, K, chunk)
    (2, 128, 2, 64, 32),
    (1, 256, 4, 64, 64),
    (2, 64, 2, 128, 64),
    (1, 96, 3, 32, 32),
]


def _inputs(B, S, H, K, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed + S + K), 5)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, K)) * 0.5
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.8 - 2.0)
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    return r, k, v, w_log, u


@pytest.mark.parametrize("B,S,H,K,chunk", CASES)
def test_kernel_matches_sequential_oracle(B, S, H, K, chunk):
    r, k, v, w_log, u = _inputs(B, S, H, K)
    y, s = wkv(r, k, v, w_log, u, chunk=chunk)
    yr, sr = wkv6_ref(r, k, v, w_log, u)
    ys = float(jnp.max(jnp.abs(yr))) + 1e-9
    ss = float(jnp.max(jnp.abs(sr))) + 1e-9
    assert float(jnp.max(jnp.abs(y - yr))) / ys < 2e-3
    assert float(jnp.max(jnp.abs(s - sr))) / ss < 2e-3


def test_jnp_chunked_matches_oracle():
    r, k, v, w_log, u = _inputs(2, 128, 2, 64, seed=7)
    y, s = wkv6_chunked(r, k, v, w_log, u, 32)
    yr, sr = wkv6_ref(r, k, v, w_log, u)
    assert float(jnp.max(jnp.abs(y - yr))) < 2e-3 * (
        float(jnp.max(jnp.abs(yr))) + 1e-9)


def test_strong_decay_stability():
    """Aggressive decays (the clamp regime) stay finite and close."""
    B, S, H, K = 1, 64, 2, 64
    r, k, v, _, u = _inputs(B, S, H, K, seed=11)
    w_log = jnp.full((B, S, H, K), -4.0)   # very fast forgetting
    y, s = wkv(r, k, v, w_log, u, chunk=32)
    yr, sr = wkv6_ref(r, k, v, w_log, u)
    assert jnp.all(jnp.isfinite(y))
    assert float(jnp.max(jnp.abs(y - yr))) < 2e-3 * (
        float(jnp.max(jnp.abs(yr))) + 1e-9)
