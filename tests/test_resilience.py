"""Resilience layer: retry/backoff, fault taxonomy, deadline ladder,
checkpoint integrity + fallback, plan-cache degradation — every
recovery path pushed through a real injected failure (the chaos-soak
composition lives in tests/test_chaos_soak.py)."""
import json
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointCorruptError,
                                      CheckpointManager)
from repro.obs import TraceRecorder, to_chrome_trace
from repro.resilience import (DeadlineMonitor, Fault, FaultPlan,
                              RetriesExhausted, TransientIOFault,
                              apply_offline_fault, corrupt_checkpoint,
                              corrupt_plan_cache, retry_transient)

# ------------------------------------------------------------- retry


def test_retry_transient_recovers_and_reports():
    calls, retries = [], []
    flaky = TransientIOFault(count=2)

    def fn():
        calls.append(1)
        flaky("read", "x")
        return 42

    out = retry_transient(fn, attempts=3, base_delay=0.0,
                          on_retry=lambda k, e, d: retries.append(k),
                          sleep=lambda s: None)
    assert out == 42 and len(calls) == 3 and retries == [1, 2]


def test_retry_transient_exhaustion_wraps_last_error():
    flaky = TransientIOFault(count=99)
    with pytest.raises(RetriesExhausted) as ei:
        retry_transient(lambda: flaky("read", "x"), attempts=3,
                        base_delay=0.0, sleep=lambda s: None)
    assert isinstance(ei.value.__cause__, OSError)


def test_retry_transient_does_not_catch_corruption():
    # corruption is not transient: non-OSError types pass straight out
    def fn():
        raise ValueError("checksum mismatch")

    with pytest.raises(ValueError):
        retry_transient(fn, attempts=5, base_delay=0.0,
                        sleep=lambda s: None)


def test_retry_backoff_is_exponential_and_capped():
    delays = []
    flaky = TransientIOFault(count=4)
    retry_transient(lambda: flaky("w", "x"), attempts=5,
                    base_delay=0.01, max_delay=0.03, jitter=0.0,
                    sleep=delays.append)
    assert delays == [0.01, 0.02, 0.03, 0.03]


# ------------------------------------------------------- fault plans


def test_fault_plan_is_one_shot_and_traced():
    rec = TraceRecorder()
    plan = FaultPlan([Fault(2, "nan_loss"), Fault(2, "straggler",
                                                  duration_s=0.5),
                      Fault(5, "preempt")], seed=7, trace=rec)
    assert [f.kind for f in plan.take(2)] == ["nan_loss", "straggler"]
    assert plan.take(2) == []          # one-shot: the retry is clean
    assert not plan.done()
    assert [f.kind for f in plan.take(5)] == ["preempt"]
    assert plan.done()
    names = [i.name for i in rec.instants]
    assert names == ["chaos_nan_loss", "chaos_straggler",
                     "chaos_preempt"]
    assert all(i.track == "chaos" for i in rec.instants)


def test_fault_kind_is_validated():
    with pytest.raises(ValueError):
        Fault(0, "gamma_ray")


# --------------------------------------------------- deadline ladder


def test_deadline_ladder_escalates_and_resets():
    rec = TraceRecorder()
    mon = DeadlineMonitor(deadline_s=1.0, warn_after=2, shed_after=4,
                          trace=rec)
    assert mon.observe(0, 0.5) == "ok"
    # four consecutive overruns walk record -> warn -> warn -> shed
    assert [mon.observe(i, 2.0) for i in range(1, 5)] == [
        "record", "warn", "warn", "shed"]
    # shed resets the consecutive count: ladder starts over
    assert mon.observe(5, 2.0) == "record"
    # meeting the deadline also resets
    assert mon.observe(6, 0.9) == "ok"
    assert mon.observe(7, 1.1) == "record"
    s = mon.summary()
    assert s["overruns"] == 6 and s["n_shed"] == 1
    assert s["worst_overrun_s"] == pytest.approx(1.0)
    names = [i.name for i in rec.instants]
    assert names.count("deadline_shed") == 1
    assert names.count("deadline_warn") == 2


def test_serve_shed_batch_slices_the_batch_axis():
    """Shedding is spec-driven: exactly the axis labelled ``batch`` in
    lm.cache_spec shrinks (stacked-layer caches carry it at index 1),
    every other axis is untouched."""
    from conftest import tiny_cfg
    from repro.launch.serve import shed_batch
    from repro.models import lm as lm_mod
    from repro.models.spec import is_par

    cfg = tiny_cfg("qwen2-0.5b", num_layers=2)
    B, L = 4, 24
    cache = lm_mod.init_cache(cfg, B, L)
    tok = jnp.zeros((B,), jnp.int32)
    cache2, tok2 = shed_batch(cfg, cache, tok, 2, L)
    assert tok2.shape == (2,)
    spec = lm_mod.cache_spec(cfg, B, L)
    import jax as _jax
    for par, before, after in zip(
            _jax.tree.leaves(spec, is_leaf=is_par),
            _jax.tree.leaves(cache), _jax.tree.leaves(cache2)):
        for ax, name in enumerate(par.axes):
            want = 2 if name == "batch" else before.shape[ax]
            assert after.shape[ax] == want, (par.axes, before.shape,
                                             after.shape)


# ------------------------------------------- checkpoint integrity


def _tree(scale=1.0):
    return {"w": jnp.arange(32.0).reshape(4, 8) * scale,
            "b": jnp.ones((8,), jnp.float32) * scale,
            "n": jnp.int32(3)}


def test_checkpoint_checksums_written_and_verified(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree())
    manifest = json.loads(
        (tmp_path / "step_1" / "manifest.json").read_text())
    assert len(manifest["checksums"]) == manifest["n_leaves"] == 3
    assert cm.verify(1) is True


@pytest.mark.parametrize("mode", ["manifest", "array", "truncate",
                                  "partial"])
def test_corruption_modes_are_detected(tmp_path, mode):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree())
    corrupt_checkpoint(tmp_path, step=1, mode=mode)
    with pytest.raises(CheckpointCorruptError):
        cm.verify(1)
    # explicit-step restore is an exact request: no silent fallback
    with pytest.raises(CheckpointCorruptError):
        cm.restore(_tree(), step=1)


def test_restore_falls_back_to_newest_intact(tmp_path):
    rec = TraceRecorder()
    cm = CheckpointManager(str(tmp_path), trace=rec)
    cm.save(1, _tree(1.0))
    cm.save(2, _tree(2.0))
    cm.save(3, _tree(3.0))
    corrupt_checkpoint(tmp_path, step=3, mode="truncate")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        restored, step = cm.restore(_tree())
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_tree(2.0)["w"]))
    names = [i.name for i in rec.instants]
    assert "ckpt_fallback" in names and "ckpt_restored" in names


def test_restore_survives_bogus_latest_pointer(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree(1.0))
    corrupt_checkpoint(tmp_path, step=1, mode="latest")
    assert cm.latest_step() == 1        # pointer ignored, dir scanned
    _, step = cm.restore(_tree())
    assert step == 1


def test_restore_raises_when_everything_is_corrupt(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree())
    cm.save(2, _tree())
    corrupt_checkpoint(tmp_path, step=1, mode="manifest")
    corrupt_checkpoint(tmp_path, step=2, mode="array")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with pytest.raises(CheckpointCorruptError):
            cm.restore(_tree())


def test_background_save_error_reraised_on_wait(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.fault_hook = TransientIOFault(count=99)   # persistent failure
    cm.io_base_delay = 0.0
    cm.save(1, _tree(), blocking=False)
    with pytest.raises(RetriesExhausted):
        cm.wait()
    # the error is consumed: a later save/wait cycle works
    cm.fault_hook = None
    cm.save(2, _tree(), blocking=False)
    cm.wait()
    assert cm.latest_step() == 2


def test_transient_io_during_save_is_absorbed_and_traced(tmp_path):
    rec = TraceRecorder()
    cm = CheckpointManager(str(tmp_path), trace=rec,
                           io_base_delay=0.0)
    cm.fault_hook = TransientIOFault(count=2)    # < io_attempts
    cm.save(1, _tree())
    assert cm.verify(1) is True
    retries = [i for i in rec.instants if i.name == "io_retry"]
    assert len(retries) == 2
    assert any(i.name == "ckpt_saved" for i in rec.instants)


def test_transient_io_during_restore_is_absorbed(tmp_path):
    cm = CheckpointManager(str(tmp_path), io_base_delay=0.0)
    cm.save(1, _tree(5.0))
    cm.fault_hook = TransientIOFault(count=2)
    restored, step = cm.restore(_tree())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_tree(5.0)["w"]))


# --------------------------------------------- plan-cache chaos


def test_corrupt_plan_cache_degrades_to_empty(tmp_path):
    from repro.tuning.plan_cache import PlanCache
    path = tmp_path / "plans.json"
    for mode in ("garbage", "schema"):
        corrupt_plan_cache(path, mode=mode)
        pc = PlanCache(str(path))
        with pytest.warns(RuntimeWarning):
            assert pc.get("spm_matmul|whatever|abc") is None
        assert len(pc) == 0 and pc.misses == 1


def test_plan_cache_transient_read_retried(tmp_path):
    from repro.tuning.plan_cache import PlanCache
    path = tmp_path / "plans.json"
    pc = PlanCache(str(path))
    pc.put("k|sig|env", {"bm": 128})
    pc.save()
    pc2 = PlanCache(str(path))
    pc2.fault_hook = TransientIOFault(count=2)
    assert pc2.get("k|sig|env") == {"bm": 128}
    assert pc2.fault_hook.raised == 2


def test_plan_cache_persistent_read_failure_degrades(tmp_path):
    from repro.tuning.plan_cache import PlanCache
    path = tmp_path / "plans.json"
    pc = PlanCache(str(path))
    pc.put("k|sig|env", {"bm": 128})
    pc.save()
    pc2 = PlanCache(str(path))
    pc2.fault_hook = TransientIOFault(count=99)
    with pytest.warns(RuntimeWarning):
        assert pc2.get("k|sig|env") is None   # degraded, not crashed


# --------------------------------------------- offline fault helper


def test_apply_offline_fault_traces_and_damages(tmp_path):
    rec = TraceRecorder()
    cm = CheckpointManager(str(tmp_path / "ckpt"))
    cm.save(4, _tree())
    hit = apply_offline_fault(Fault(4, "ckpt_corrupt", mode="array"),
                              ckpt_dir=cm.dir, trace=rec)
    assert hit == 4
    with pytest.raises(CheckpointCorruptError):
        cm.verify(4)
    assert [i.name for i in rec.instants] == ["chaos_ckpt_corrupt"]
    with pytest.raises(ValueError):
        apply_offline_fault(Fault(0, "preempt"), trace=rec)


def test_chaos_instants_export_to_chrome_trace():
    rec = TraceRecorder()
    plan = FaultPlan([Fault(1, "nan_loss")], trace=rec)
    plan.take(1)
    doc = to_chrome_trace(rec)
    ev = [e for e in doc["traceEvents"] if e.get("ph") == "i"]
    assert any(e["name"] == "chaos_nan_loss" for e in ev)
