"""Validation against the paper's own published claims (§5, Fig. 3-5,
Tables 1-2) — the faithful-reproduction gate.

Anchors (exact numbers printed in the paper):
  * Octa:     728,548,804 cycles median @ 168 MHz ~ 4.33 s
  * Hexadeca: 548,343,601 cycles median @ 118 MHz ~ 4.65 s
Claims (qualitative, all asserted):
  * median cycles decrease monotonically with core count,
  * execution-time std-dev is small and grows with core count,
  * Octa is optimal in wall-clock at F_max; multi-core beats the
    single-core Fast baseline,
  * multi-core variants share the Fast compute ceiling but shift the
    SPM-bandwidth roofline (Fig. 3),
  * F_max model reproduces Tables 1-2 within 5%,
  * resource trends (Fig. 5): totals grow with cores, DSPs roughly
    flat, workers dominate the management core.
"""
import numpy as np
import pytest

from repro.configs.multivic_paper import (BASELINE_FAST, DUAL, EVAL_CONFIGS,
                                          HEXADECA, OCTA,
                                          PAPER_MEDIAN_CYCLES, QUAD)
from repro.core.fmax import model_table, predict_fmax_mhz
from repro.core.resources import component_resources, total_resources
from repro.core.roofline import config_roofline
from repro.core.scheduler import MatmulProblem, build_matmul_schedule
from repro.core.simulator import run_many

N_RUNS = 15


@pytest.fixture(scope="module")
def results():
    out = {}
    for hw in EVAL_CONFIGS:
        sched = build_matmul_schedule(hw, MatmulProblem())
        out[hw.name] = run_many(sched, hw, n_runs=N_RUNS)
    return out


def test_absolute_cycle_anchors(results):
    for name, target in PAPER_MEDIAN_CYCLES.items():
        got = results[name]["median"]
        assert abs(got / target - 1) < 0.005, (name, got, target)


def test_median_cycles_decrease_with_cores(results):
    order = ["baseline-fast", "dual", "quad", "octa", "hexadeca"]
    meds = [results[n]["median"] for n in order]
    assert all(a > b for a, b in zip(meds, meds[1:])), meds


def test_variability_small_and_growing(results):
    order = ["baseline-fast", "dual", "quad", "octa", "hexadeca"]
    stds = [results[n]["std"] for n in order]
    meds = [results[n]["median"] for n in order]
    for s, m in zip(stds, meds):
        assert s / m < 1e-4          # "very low" relative variability
    assert stds[-1] > stds[0]        # grows with core count


def test_octa_optimal_at_fmax(results):
    secs = {hw.name: results[hw.name]["median"] / hw.fmax_hz
            for hw in EVAL_CONFIGS}
    assert min(secs, key=secs.get) == "octa", secs
    assert secs["octa"] < secs["baseline-fast"]   # multi-core wins
    assert abs(secs["octa"] - 4.33) < 0.05
    assert abs(secs["hexadeca"] - 4.65) < 0.05


def test_roofline_fig3_claims():
    fast = config_roofline(BASELINE_FAST, use_fmax=False)
    for hw in (DUAL, QUAD, OCTA, HEXADECA):
        r = config_roofline(hw, use_fmax=False)
        # same total compute (total MUL width constant at 1024 bits)
        assert abs(r["peak_gflops"] / fast["peak_gflops"] - 1) < 1e-9
        # SPM bandwidth scales with core count -> boundary shifts
        assert abs(r["spm_bw_gbs"] / fast["spm_bw_gbs"]
                   - hw.num_worker_cores) < 1e-9


def test_fmax_model_fits_tables():
    for name, meas, pred, err in model_table():
        assert abs(err) < 0.05, (name, meas, pred)


def test_fmax_congestion_at_16_cores():
    # the paper's scalability limit: 16 cores lose >25% clock vs 8
    assert predict_fmax_mhz(HEXADECA) < 0.8 * predict_fmax_mhz(OCTA)


def test_resource_trends_fig5():
    totals = [total_resources(hw) for hw in
              (BASELINE_FAST, DUAL, QUAD, OCTA, HEXADECA)]
    luts = [t["lut"] for t in totals]
    assert all(a <= b for a, b in zip(luts[1:], luts[2:]))  # grows w/ W
    dsps = [t["dsp"] for t in totals]
    assert max(dsps) / min(dsps) < 1.6   # "roughly flat" DSP count
    comps = component_resources(DUAL)
    assert comps["workers"]["lut"] > 5 * comps["mgmt_core"]["lut"]
    assert comps["workers"]["bram"] > comps["mgmt_core"]["bram"]
