"""Model-level serving plans (repro.tuning.model / model_tuner):
cache round-trip, resolution precedence, backend isolation, corrupt
degradation, schema tolerance, and the WCET-derives-from-plan claim
the serve banner makes.

Measured cases use a micro problem (2 layers, d_model 64) so a full
tune is a handful of tiny prefill+decode passes.
"""
import json

import pytest

from repro import tuning
from repro.tuning import (ModelProblem, PlanCache, default_model_plan,
                          enumerate_model_candidates, measurement_count,
                          model_cache_key, parse_model_problem,
                          problem_config, resolve_model_plan,
                          tune_model)
from repro.tuning.model import (MODEL_NS, model_analytic_cost_s,
                                model_feasible)
from repro.tuning.plan_cache import env_fingerprint, env_sig

MICRO = ModelProblem("qwen2-0.5b", 2, 32, 4, layers=2, d_model=64,
                     vocab=256)


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Fresh cache file + re-enabled autotuning + clean singleton."""
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    tuning.reset()
    yield path
    tuning.reset()


# --------------------------------------------------------- problem/sig

def test_problem_sig_and_parse_round_trip():
    assert MICRO.sig == "qwen2-0.5b-b2p32g4-l2d64v256-float32"
    assert parse_model_problem("qwen2-0.5b", "2x32x4", d_model=64,
                               vocab=256) == MICRO
    full = ModelProblem("qwen2-0.5b", 4, 64, 8, layers=0)
    assert "full" in full.sig
    with pytest.raises(ValueError):
        parse_model_problem("qwen2-0.5b", "2x32")


def test_cache_key_uses_model_namespace():
    key = model_cache_key(MICRO)
    assert key.startswith(f"{MODEL_NS}|{MICRO.sig}|")
    assert key.endswith(env_sig())


# --------------------------------------------- candidates + cost model

def test_candidates_include_default_and_divide_prompt(tmp_cache):
    cfg = problem_config(MICRO)
    cands = enumerate_model_candidates(cfg, MICRO)
    assert default_model_plan(cfg, MICRO) in cands
    for plan in cands:
        assert MICRO.prompt_len % plan["chunk_q"] == 0
        assert MICRO.prompt_len % plan["chunk_kv"] == 0
        assert plan["decode_scan"] in (0, 1)
        assert plan["mm_bm"] >= 1 and plan["mm_bn"] >= 1


def test_feasibility_and_cost_respond_to_chunking(tmp_cache):
    # long enough that an unchunked prefill working set (flash never
    # materializes scores, so only the Q/K/V tiles count) overflows
    # the 128 MiB VMEM budget
    P = 262144
    prob = ModelProblem("qwen2-0.5b", 8, P, 4, layers=2,
                        d_model=128, vocab=512)
    cfg = problem_config(prob)
    base = default_model_plan(cfg, prob)
    assert model_feasible(cfg, prob, base)
    fat = dict(base, chunk_q=P, chunk_kv=P)
    assert not model_feasible(cfg, prob, fat)
    # more decode steps cost more; chunking only affects prefill
    prob2 = ModelProblem("qwen2-0.5b", 8, P, 64, layers=2,
                         d_model=128, vocab=512)
    assert model_analytic_cost_s(cfg, prob2, base) \
        > model_analytic_cost_s(cfg, prob, base)


# ------------------------------------------------- cache + resolution

def test_cache_round_trip_and_precedence(tmp_cache):
    cfg = problem_config(MICRO)
    default = default_model_plan(cfg, MICRO)

    # defaults when cold
    r = resolve_model_plan(cfg, MICRO)
    assert r["source"] == "defaults" and r["plan"] == default

    # cached plan wins over defaults
    tuned = dict(default, chunk_q=16, decode_scan=1 - default["decode_scan"])
    cache = tuning.active_cache()
    cache.put(model_cache_key(MICRO), tuned, kernel="model")
    cache.save()
    tuning.reset()
    r = resolve_model_plan(problem_config(MICRO), MICRO)
    assert r["source"] == "cache" and r["plan"] == tuned

    # explicit overrides win over the cache
    r = resolve_model_plan(problem_config(MICRO), MICRO,
                           {"chunk_q": 8, "chunk_kv": None})
    assert r["plan"]["chunk_q"] == 8
    assert r["plan"]["chunk_kv"] == tuned["chunk_kv"]
    assert r["source"] == "explicit+cache"


def test_autotune_disabled_ignores_cache(tmp_cache, monkeypatch):
    cfg = problem_config(MICRO)
    default = default_model_plan(cfg, MICRO)
    cache = tuning.active_cache()
    cache.put(model_cache_key(MICRO), dict(default, chunk_q=16),
              kernel="model")
    cache.save()
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    tuning.reset()
    r = resolve_model_plan(cfg, MICRO)
    assert r["source"] == "defaults" and r["plan"] == default


def test_backend_keyed_isolation(tmp_cache):
    """A plan tuned under a different backend fingerprint (e.g. a TPU
    plan read on this CPU host) must not resolve."""
    cfg = problem_config(MICRO)
    default = default_model_plan(cfg, MICRO)
    other_env = env_sig(dict(env_fingerprint(), backend="tpu"))
    assert other_env != env_sig()
    foreign_key = f"{MODEL_NS}|{MICRO.sig}|{other_env}"
    cache = tuning.active_cache()
    cache.put(foreign_key, dict(default, chunk_q=16), kernel="model")
    cache.save()
    tuning.reset()
    r = resolve_model_plan(problem_config(MICRO), MICRO)
    assert r["source"] == "defaults" and r["plan"] == default


def test_corrupt_entry_degrades_to_defaults(tmp_cache):
    cfg = problem_config(MICRO)
    default = default_model_plan(cfg, MICRO)
    cache = tuning.active_cache()
    cache.put(model_cache_key(MICRO), dict(default, chunk_q=16),
              kernel="model")
    cache.save()
    doc = json.loads(tmp_cache.read_text(encoding="utf-8"))
    doc["plans"][model_cache_key(MICRO)]["plan"] = {"chunk_q": "wat"}
    tmp_cache.write_text(json.dumps(doc), encoding="utf-8")
    tuning.reset()
    with pytest.warns(RuntimeWarning, match="mis-shaped"):
        r = resolve_model_plan(problem_config(MICRO), MICRO)
    assert r["source"] == "defaults" and r["plan"] == default


def test_schema_v1_cache_still_read(tmp_cache):
    """PR 10 bumped the cache schema to v2 (model| namespace); files
    written by the v1 tuner must load without warnings."""
    cfg = problem_config(MICRO)
    default = default_model_plan(cfg, MICRO)
    tuned = dict(default, chunk_q=16)
    key = model_cache_key(MICRO)
    doc = {"schema_version": 1,
           "plans": {key: {"plan": tuned, "kernel": "model"}}}
    tmp_cache.write_text(json.dumps(doc), encoding="utf-8")
    tuning.reset()
    r = resolve_model_plan(cfg, MICRO)
    assert r["source"] == "cache" and r["plan"] == tuned


# ------------------------------------------------------ tuning (slow-ish)

def test_tune_model_cold_then_warm(tmp_cache):
    from repro.obs import TraceRecorder
    tr = TraceRecorder()
    res = tune_model(MICRO, reps=2, warmup=1, max_candidates=2,
                     trace=tr)
    assert res.source == "measured"
    assert res.measured > 0
    assert measurement_count(tr) == res.measured
    assert res.stats is not None and res.default_stats is not None
    assert set(res.plan) == {"chunk_q", "chunk_kv", "decode_scan",
                             "mm_bm", "mm_bn"}

    # warm: same plan, zero measurements, zero spans
    tr2 = TraceRecorder()
    res2 = tune_model(MICRO, reps=2, trace=tr2)
    assert res2.source == "cache"
    assert res2.measured == 0 and measurement_count(tr2) == 0
    assert res2.plan == res.plan

    # and the serving resolution picks the tuned plan up
    r = resolve_model_plan(problem_config(MICRO), MICRO)
    assert r["source"] == "cache" and r["plan"] == res.plan


def test_decode_scan_plans_are_equivalent(tmp_cache):
    """scan-vs-unroll is a schedule choice, not a semantics choice:
    both plans must produce identical serve outputs."""
    import numpy as np

    from repro.tuning.model_tuner import make_serve_runner

    cfg = problem_config(MICRO)
    base = default_model_plan(cfg, MICRO)

    def run_decode(plan):
        import jax
        import jax.numpy as jnp

        from repro.models import lm as lm_mod
        from repro.models.lm import RunOptions
        opts = RunOptions(chunk_q=int(plan["chunk_q"]),
                          chunk_kv=int(plan["chunk_kv"]),
                          cache_len=MICRO.prompt_len + MICRO.gen,
                          remat=False,
                          decode_scan=bool(plan["decode_scan"]))
        key = jax.random.PRNGKey(0)
        params = lm_mod.init_params(cfg, key)
        tokens = jax.random.randint(key, (MICRO.batch,
                                          MICRO.prompt_len),
                                    0, cfg.vocab_size)
        batch = {"tokens": tokens, "targets": tokens}
        logits, cache = lm_mod.prefill(cfg, params, batch, opts)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        toks = []
        for i in range(MICRO.gen):
            logits, cache = lm_mod.decode_step(
                cfg, params, cache, tok, MICRO.prompt_len + i, opts)
            tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
            toks.append(np.asarray(tok))
        return np.stack(toks, 1), np.asarray(logits)

    scan_toks, scan_logits = run_decode(dict(base, decode_scan=1))
    unroll_toks, unroll_logits = run_decode(dict(base, decode_scan=0))
    # greedy trajectories must match exactly; logits to bf16 accuracy
    # (the model computes in bfloat16, and scan vs unroll reassociates
    # the per-layer accumulation)
    np.testing.assert_array_equal(scan_toks, unroll_toks)
    np.testing.assert_allclose(scan_logits, unroll_logits, atol=3e-2)

    # the AOT serve runner accepts both loop structures
    make_serve_runner(cfg, MICRO, dict(base, decode_scan=0))()


# -------------------------------------------------- WCET <- plan link

def test_wcet_bound_derives_from_the_served_plan(tmp_cache):
    """The serve banner's bound must be a function of the resolved
    plan: same helper, different plan pins -> different bound."""
    from repro.launch.serve import plan_wcet_s
    from repro.models.lm import param_count

    cfg = problem_config(MICRO)
    n_p = param_count(cfg)
    resolved = resolve_model_plan(cfg, MICRO)["plan"]
    w_resolved = plan_wcet_s(cfg, resolved, MICRO.batch, n_p)
    assert w_resolved > 0
    # finer N tiling re-streams A once per extra column block, so the
    # bound must move with the pins (the default pin is the full-N
    # clamp ceiling — widening it would be clamped back to no-op)
    repinned = dict(resolved, mm_bn=max(1, resolved["mm_bn"] // 2))
    w_repinned = plan_wcet_s(cfg, repinned, MICRO.batch, n_p)
    assert w_repinned != w_resolved

    # and the schedule metadata records exactly the served tiles
    from repro.core.tpu_mapping import serve_step_schedule
    sched = serve_step_schedule(MICRO.batch, cfg.d_model, n_p,
                                plan=resolved)
    assert sched.meta["tile_m"] == min(resolved["mm_bm"], MICRO.batch)


def test_committed_bench_report_has_tuned_serve_win():
    """The acceptance artifact: the newest committed BENCH report must
    show the tuned serving plan strictly faster than the default, with
    CoV no worse than the bench_diff predictability slack."""
    import pathlib

    repo = pathlib.Path(__file__).resolve().parent.parent
    reports = []
    for path in repo.glob("BENCH_*.json"):
        doc = json.loads(path.read_text(encoding="utf-8"))
        reports.append((float(doc.get("generated_at") or 0.0), doc))
    assert reports, "no committed BENCH_*.json found"
    newest = max(reports, key=lambda td: td[0])[1]
    rows = {b["name"]: b for b in newest["benchmarks"]
            if b["name"].startswith("serve/")}
    assert rows, "newest BENCH report carries no serve_steps rows"
    tuned = [n for n in rows if n.endswith("_tuned")]
    assert tuned
    for name in tuned:
        t = rows[name]
        d = rows[name.replace("_tuned", "_default")]
        assert t["us_per_call"] < d["us_per_call"], (name, t, d)
        assert t["jitter"]["cov"] <= d["jitter"]["cov"] + 0.02, \
            (name, t["jitter"]["cov"], d["jitter"]["cov"])
