"""Unit tests for the repro.compat version seam: each shim must resolve
the right symbol under BOTH the old (jax 0.4.x) and new (jax >= 0.5)
attribute layouts, exercised via synthetic module objects so the tests
pass regardless of the installed JAX.

Note: raw symbol names are built by concatenation — the compat-import
lint (scripts/check_compat_imports.py) greps for the literal spellings.
"""
import types

import pytest

from repro import compat

_OLD_CP = "TPUCompiler" + "Params"     # jax <= 0.4.x spelling
_NEW_CP = "Compiler" + "Params"        # jax >= 0.5 spelling


# ------------------------------------------------ compiler params class

def _fake_pltpu(**attrs):
    mod = types.SimpleNamespace()
    for name, val in attrs.items():
        setattr(mod, name, val)
    return mod


def test_resolves_old_compiler_params_layout():
    class Old:
        pass
    mod = _fake_pltpu(**{_OLD_CP: Old})
    assert compat._resolve_tpu_compiler_params_cls(mod) is Old


def test_resolves_new_compiler_params_layout():
    class New:
        pass
    mod = _fake_pltpu(**{_NEW_CP: New})
    assert compat._resolve_tpu_compiler_params_cls(mod) is New


def test_new_layout_wins_when_both_exist():
    class Old:
        pass

    class New:
        pass
    mod = _fake_pltpu(**{_OLD_CP: Old, _NEW_CP: New})
    assert compat._resolve_tpu_compiler_params_cls(mod) is New


def test_missing_layout_raises():
    with pytest.raises(AttributeError):
        compat._resolve_tpu_compiler_params_cls(_fake_pltpu())


def test_tpu_compiler_params_real_jax():
    p = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert tuple(p.dimension_semantics) == ("parallel", "arbitrary")


def test_tpu_compiler_params_drops_unknown_fields():
    p = compat.tpu_compiler_params(
        dimension_semantics=("arbitrary",),
        some_future_field_this_jax_lacks=123)
    assert tuple(p.dimension_semantics) == ("arbitrary",)


# ------------------------------------------------------ mesh / AxisType

def test_axis_type_has_auto():
    assert hasattr(compat.AxisType, "Auto")
    assert compat.auto_axis_types(3) == (compat.AxisType.Auto,) * 3


def test_mesh_kwargs_old_signature_drops_axis_types():
    old_sig = frozenset({"axis_shapes", "axis_names", "devices"})
    kw = compat._mesh_kwargs(old_sig, compat.auto_axis_types(2), None)
    assert kw == {}


def test_mesh_kwargs_new_signature_passes_axis_types():
    new_sig = frozenset({"axis_shapes", "axis_names", "devices",
                         "axis_types"})
    types_ = compat.auto_axis_types(2)
    kw = compat._mesh_kwargs(new_sig, types_, None)
    assert kw == {"axis_types": types_}


def test_make_mesh_real_jax_single_device():
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape == {"data": 1, "model": 1}


# -------------------------------------------------------- cost analysis

def test_normalize_cost_analysis_old_list_shape():
    raw = [{"flops": 10.0, "bytes accessed": 5.0, "utilization0{}": 1.0}]
    ca = compat.normalize_cost_analysis(raw)
    assert ca["flops"] == 10.0
    assert ca["bytes accessed"] == 5.0


def test_normalize_cost_analysis_new_dict_shape():
    ca = compat.normalize_cost_analysis({"flops": 7, "transcendentals": 1})
    assert ca == {"flops": 7.0, "transcendentals": 1.0}


def test_normalize_cost_analysis_degenerate():
    assert compat.normalize_cost_analysis(None) == {}
    assert compat.normalize_cost_analysis([]) == {}
    assert compat.normalize_cost_analysis({"weird": object()}) == {}


def test_cost_analysis_real_compiled_program():
    import jax
    import jax.numpy as jnp
    c = jax.jit(lambda x: (x @ x).sum()).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    ca = compat.cost_analysis(c)
    assert ca.get("flops", 0.0) > 0.0


# ---------------------------------------------------- interpret select

def test_resolve_interpret_explicit_passthrough():
    assert compat.resolve_interpret(True) is True
    assert compat.resolve_interpret(False) is False


def test_resolve_interpret_auto_off_tpu(monkeypatch):
    monkeypatch.setattr(compat, "on_tpu", lambda: False)
    assert compat.resolve_interpret(None) is True
    monkeypatch.setattr(compat, "on_tpu", lambda: True)
    assert compat.resolve_interpret(None) is False


# ----------------------------------------------------------- shard_map

def test_shard_map_kwargs_old_layout():
    params = frozenset({"f", "mesh", "in_specs", "out_specs",
                        "check_rep", "auto"})
    kw = compat._shard_map_kwargs(params, check=False,
                                  auto=frozenset({"data"}),
                                  axis_names=("pod", "data"))
    assert kw == {"check_rep": False, "auto": frozenset({"data"})}


def test_shard_map_kwargs_new_layout():
    params = frozenset({"f", "mesh", "in_specs", "out_specs",
                        "check_vma", "axis_names"})
    kw = compat._shard_map_kwargs(params, check=False,
                                  auto=frozenset({"data"}),
                                  axis_names=("pod", "data"))
    assert kw == {"check_vma": False, "axis_names": {"pod"}}


def test_shard_map_real_jax_runs():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("data",))
    fn = compat.shard_map(lambda x: x * 2, mesh, (P("data"),),
                          P("data"))
    out = jax.jit(fn)(jnp.arange(4.0))
    assert jnp.allclose(out, jnp.arange(4.0) * 2)
