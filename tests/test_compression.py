"""int8 cross-pod gradient compression: error bound + multi-device
mean correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim.compression import quantize_roundtrip


@given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e3))
@settings(max_examples=30, deadline=None)
def test_quantization_error_bound(seed, scale):
    g = scale * jax.random.normal(jax.random.PRNGKey(seed), (256,))
    gq = quantize_roundtrip(g)
    amax = float(jnp.max(jnp.abs(g)))
    # uniform quantizer: |err| <= step/2 = amax/127/2 (+eps)
    assert float(jnp.max(jnp.abs(gq - g))) <= amax / 127.0 / 2 + 1e-6


def test_zero_grads_stay_zero():
    g = jnp.zeros((64,))
    assert jnp.all(quantize_roundtrip(g) == 0)


def test_compressed_mean_multipod():
    """2-pod mean via the int8 wire format, on real host devices: pod 0
    holds g, pod 1 holds 3g -> compressed mean ~= 2g within the
    quantization bound."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import auto_axis_types, make_mesh, shard_map
from repro.optim.compression import _compress_psum_leaf
mesh = make_mesh((2, 2), ("pod", "data"),
                 axis_types=auto_axis_types(2))
g = jnp.arange(-8.0, 8.0).reshape(4, 4) / 8.0
stacked = jnp.stack([g, 3 * g])                  # [pod, ...]
fn = shard_map(
    lambda x: _compress_psum_leaf(x[0], "pod")[None],
    mesh, (P("pod", None, None),),
    P("pod", None, None))
out = jax.jit(fn)(jax.device_put(
    stacked, NamedSharding(mesh, P("pod", None, None))))
# both pods now hold the (identical) compressed mean
err = float(jnp.max(jnp.abs(out[0] - 2 * g)))
assert err <= float(jnp.max(jnp.abs(3 * g))) / 127.0 + 1e-6, err
print("OK", err)
"""
    r = subprocess.run([sys.executable, "-c", code], cwd=".",
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
