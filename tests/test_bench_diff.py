"""scripts/bench_diff.py: the BENCH-trajectory regression gate on two
synthetic schema-v1 reports (faster/slower/noisier variants)."""
import importlib.util
import json
import pathlib

from repro.obs import jitter_stats
from repro.obs.report import make_report

_SCRIPT = (pathlib.Path(__file__).resolve().parent.parent / "scripts"
           / "bench_diff.py")


def _load():
    spec = importlib.util.spec_from_file_location("bench_diff", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _report(path, rows):
    doc = make_report(rows, fast=True)
    path.write_text(json.dumps(doc), encoding="utf-8")
    return path


def _row(name, us, jitter_samples=None):
    row = {"name": name, "us_per_call": us, "derived": "x=1"}
    if jitter_samples is not None:
        row["jitter"] = jitter_stats(jitter_samples).as_dict()
    return row


def test_improvement_exits_zero(tmp_path, capsys):
    bd = _load()
    old = _report(tmp_path / "old.json",
                  [_row("kernel/a", 1000.0, [990.0, 1000.0, 1010.0]),
                   _row("bench/b", 50.0)])
    new = _report(tmp_path / "new.json",
                  [_row("kernel/a", 400.0, [396.0, 400.0, 404.0]),
                   _row("bench/b", 50.0)])
    assert bd.main([str(old), str(new)]) == bd.EXIT_OK
    out = capsys.readouterr().out
    assert "improved: kernel/a" in out
    assert "0 regression(s)" in out


def test_us_per_call_regression_exits_nonzero(tmp_path, capsys):
    bd = _load()
    old = _report(tmp_path / "old.json", [_row("kernel/a", 1000.0)])
    new = _report(tmp_path / "new.json", [_row("kernel/a", 2000.0)])
    assert bd.main([str(old), str(new)]) == bd.EXIT_REGRESSION
    assert "REGRESSION: kernel/a: us_per_call" \
        in capsys.readouterr().out


def test_abs_floor_suppresses_micro_regressions(tmp_path):
    bd = _load()
    # 3x relative growth but only +20us absolute: below the floor
    old = _report(tmp_path / "old.json", [_row("micro/x", 10.0)])
    new = _report(tmp_path / "new.json", [_row("micro/x", 30.0)])
    assert bd.main([str(old), str(new)]) == bd.EXIT_OK


def test_p99_regression_detected(tmp_path, capsys):
    bd = _load()
    old = _report(tmp_path / "old.json",
                  [_row("kernel/a", 1000.0, [990.0, 1000.0, 1010.0])])
    # mean barely moves; the tail blows up
    new = _report(tmp_path / "new.json",
                  [_row("kernel/a", 1040.0,
                        [960.0, 980.0, 1000.0, 5000.0])])
    assert bd.main([str(old), str(new)]) == bd.EXIT_REGRESSION
    assert "jitter.p99" in capsys.readouterr().out


def test_cov_regression_detected(tmp_path, capsys):
    bd = _load()
    old = _report(tmp_path / "old.json",
                  [_row("kernel/a", 1000.0,
                        [999.0, 1000.0, 1001.0])])
    # same speed, wildly unsteady: predictability gate must fire
    new = _report(tmp_path / "new.json",
                  [_row("kernel/a", 1000.0,
                        [700.0, 900.0, 1100.0, 1300.0])])
    assert bd.main([str(old), str(new)]) == bd.EXIT_REGRESSION
    assert "jitter.cov" in capsys.readouterr().out


def test_asymmetric_rows_are_notes_not_failures(tmp_path, capsys):
    bd = _load()
    old = _report(tmp_path / "old.json", [_row("only/old", 10.0)])
    new = _report(tmp_path / "new.json", [_row("only/new", 10.0)])
    assert bd.main([str(old), str(new)]) == bd.EXIT_OK
    out = capsys.readouterr().out
    assert "warning: only/old: skipped, only in old report" in out
    assert "warning: only/new: skipped, only in new report" in out


def test_invalid_inputs_exit_two(tmp_path, capsys):
    bd = _load()
    good = _report(tmp_path / "good.json", [_row("a", 1.0)])
    missing = tmp_path / "missing.json"
    assert bd.main([str(good), str(missing)]) == bd.EXIT_INVALID
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema_version": 999}),
                   encoding="utf-8")
    assert bd.main([str(bad), str(good)]) == bd.EXIT_INVALID
    err = capsys.readouterr().err
    assert "not a valid schema-v1 report" in err


def test_seed_report_diffs_clean_against_itself():
    """The committed BENCH reports must pass their own gate."""
    bd = _load()
    repo = pathlib.Path(__file__).resolve().parent.parent
    seeds = sorted(repo.glob("BENCH_*.json"))
    assert seeds, "no committed BENCH_*.json found"
    for seed in seeds:
        assert bd.main([str(seed), str(seed)]) == bd.EXIT_OK
