"""Capacity-factor MoE properties — the paper's 'static assumptions for
dynamic behaviour' must hold structurally."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import MoEConfig
from repro.models.ffn import _topk_dispatch, moe_ffn, moe_spec
from repro.models.spec import init_tree


@given(seed=st.integers(0, 1000),
       gs=st.sampled_from([16, 32]),
       E=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
@settings(max_examples=20, deadline=None)
def test_dispatch_respects_capacity(seed, gs, E, k):
    key = jax.random.PRNGKey(seed)
    gates = jax.nn.softmax(jax.random.normal(key, (2, gs, E)), -1)
    C = max(2, gs * k // E)
    combine, dispatch = _topk_dispatch(gates, k, C)
    # at most one token per (expert, slot)
    per_slot = dispatch.sum(axis=1)            # [G, E, C]
    assert float(per_slot.max()) <= 1.0 + 1e-6
    # each token routed to at most k slots
    per_token = dispatch.sum(axis=(2, 3))      # [G, S]
    assert float(per_token.max()) <= k + 1e-6
    # combine weights are within the gate simplex
    assert float(combine.sum(axis=(2, 3)).max()) <= 1.0 + 1e-5


def test_moe_static_shapes_and_aux():
    m = MoEConfig(num_experts=4, top_k=2, expert_ff=32, group_size=16,
                  capacity_factor=2.0)
    p = init_tree(moe_spec(64, m, "swiglu", "float32"),
                  jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    y, aux = moe_ffn(p, x, m, "swiglu")
    assert y.shape == x.shape
    assert jnp.isfinite(aux)
    # aux loss is ~1 for a balanced uniform router
    assert 0.5 < float(aux) < 4.0


def test_moe_deterministic():
    m = MoEConfig(num_experts=4, top_k=1, expert_ff=16, group_size=8)
    p = init_tree(moe_spec(32, m, "gelu", "float32"),
                  jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    y1, _ = moe_ffn(p, x, m, "gelu")
    y2, _ = moe_ffn(p, x, m, "gelu")
    assert jnp.array_equal(y1, y2)   # input-independent static schedule


def test_ep_matches_einsum_single_device():
    """shard_map expert parallelism == einsum dispatch (1x1 mesh)."""
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    xs = NamedSharding(mesh, P("data", None, None))
    m = MoEConfig(num_experts=8, top_k=2, expert_ff=32, group_size=32,
                  capacity_factor=8.0)
    p = init_tree(moe_spec(64, m, "swiglu", "float32"),
                  jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    y1, _ = moe_ffn(p, x, m, "swiglu", "einsum")
    y2, _ = moe_ffn(p, x, m, "swiglu", "ep", xs)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-5


def test_ep_multidevice():
    """EP correctness across real shards (8 host devices, 2x4 mesh) —
    runs in a subprocess because the device count is process-global."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import auto_axis_types, make_mesh
from repro.configs.base import MoEConfig
from repro.models.ffn import moe_ffn, moe_spec
from repro.models.spec import init_tree
mesh = make_mesh((2, 4), ("data", "model"),
                 axis_types=auto_axis_types(2))
xs = NamedSharding(mesh, P("data", None, None))
m = MoEConfig(num_experts=8, top_k=2, expert_ff=64, group_size=64,
              capacity_factor=8.0)
p = init_tree(moe_spec(64, m, "swiglu", "float32"), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 64))
y1, _ = jax.jit(lambda p, x: moe_ffn(p, x, m, "swiglu", "einsum"))(p, x)
y2, _ = jax.jit(lambda p, x: moe_ffn(p, x, m, "swiglu", "ep", xs))(
    p, jax.device_put(x, xs))
err = float(jnp.max(jnp.abs(y1 - y2)))
assert err < 2e-5, err
print("OK", err)
"""
    r = subprocess.run([sys.executable, "-c", code], cwd=".",
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
